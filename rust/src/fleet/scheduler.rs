//! The fleet scheduler: a worker pool draining a job queue behind the
//! admission gate — with preempt-to-disk, so a budget squeeze parks work
//! instead of killing it.
//!
//! Each worker pops a job, costs it, blocks until the budget admits it,
//! then runs a full [`TrainSession`] on a per-job child of the fleet-wide
//! aggregate [`MemoryTracker`]. The session's tracked bytes therefore
//! roll up into one aggregate whose peak is the fleet's true concurrent
//! high-water mark — the number the report compares against the budget.
//!
//! # Preemption
//!
//! Sessions run step by step and poll their permit between steps. When
//! the admission gate asks a job to yield — an arriving higher-priority
//! job cannot fit, or a [`BudgetChange`] from `--budget-schedule` shrank
//! the budget below the running set — the session is snapshotted to the
//! fleet snapshot dir ([`crate::persist`], bitwise-resumable), dropped
//! (releasing every tracked byte), its permit returned, and the job
//! re-enters the queue to resume later from exactly where it stopped.
//! While parked, the snapshot's on-disk bytes are tracked under the
//! `snapshot` tag on the fleet aggregate, so a memory profile shows
//! where the displaced state went.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::config::TrainConfig;
use crate::coordinator::TrainSession;
use crate::memory::{Guard, MemoryTracker};
use crate::metrics::{RunSummary, TableBuilder};
use crate::model::WeightCache;
use crate::obs::{MetricsRegistry, TraceSink};
use crate::util::json::Json;
use crate::util::stats::fmt_mb;

use super::admission::{job_cost_bytes, job_weight_class, Admission};
use super::job::Job;

/// One point of a `--budget-schedule`: once the fleet has completed
/// `at_step` optimization steps in total (across all jobs), the budget
/// becomes `budget_bytes`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BudgetChange {
    pub at_step: u64,
    pub budget_bytes: u64,
}

/// Parse the `--budget-schedule step:mb,step:mb` syntax: a comma-
/// separated list of `fleet-step:budget-MB` points, strictly ascending
/// in step. Example: `--budget-schedule 20:48,50:24` shrinks the budget
/// to 48 MB after 20 fleet-wide steps and to 24 MB after 50.
pub fn parse_budget_schedule(s: &str) -> anyhow::Result<Vec<BudgetChange>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        let (step, mb) = p.split_once(':').ok_or_else(|| {
            anyhow::anyhow!(
                "budget-schedule entry '{p}' is not step:mb (e.g. 20:48)"
            )
        })?;
        let at_step: u64 = step.trim().parse().map_err(|_| {
            anyhow::anyhow!("budget-schedule step '{step}' is not an integer")
        })?;
        let mb: u64 = mb.trim().parse().map_err(|_| {
            anyhow::anyhow!("budget-schedule budget '{mb}' is not an integer (MB)")
        })?;
        anyhow::ensure!(mb > 0, "budget-schedule budget must be positive MB");
        let budget_bytes = mb
            .checked_mul(1 << 20)
            .ok_or_else(|| anyhow::anyhow!("budget-schedule {mb} MB overflows"))?;
        out.push(BudgetChange { at_step, budget_bytes });
    }
    anyhow::ensure!(!out.is_empty(), "empty budget schedule '{s}'");
    for w in out.windows(2) {
        anyhow::ensure!(
            w[0].at_step < w[1].at_step,
            "budget-schedule steps must be strictly ascending ({} then {})",
            w[0].at_step,
            w[1].at_step
        );
    }
    Ok(out)
}

/// Fleet-wide knobs (the job list and base `TrainConfig` ride separately).
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Shared device budget in bytes: the sum of predicted peak memory of
    /// all concurrently-admitted jobs stays under this.
    pub budget_bytes: u64,
    /// Worker threads draining the queue (clamped to the job count).
    pub workers: usize,
    /// Allow arriving higher-priority jobs to preempt running
    /// lower-priority jobs (snapshot → requeue → resume). Implied by a
    /// non-empty `budget_schedule`.
    pub preempt: bool,
    /// Where preempted sessions park their snapshots (default: a
    /// per-process temp directory).
    pub snapshot_dir: Option<PathBuf>,
    /// Mid-run budget changes, keyed by total fleet steps completed.
    pub budget_schedule: Vec<BudgetChange>,
    /// Write a fleet-wide Chrome trace here (`--trace`): one shared sink,
    /// every event tagged with its job id. `None` disables tracing.
    pub trace_path: Option<PathBuf>,
    /// Write the fleet-wide metrics-registry JSONL snapshot here
    /// (`--metrics-out`). `None` skips the export (the registry still
    /// rides along in the report).
    pub metrics_out: Option<PathBuf>,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            budget_bytes: u64::MAX,
            workers: 1,
            preempt: false,
            snapshot_dir: None,
            budget_schedule: Vec::new(),
            trace_path: None,
            metrics_out: None,
        }
    }
}

/// What one finished job produced. For a job that was preempted along
/// the way, `summary`/`losses` cover the FINAL run segment (from its
/// last resume to completion) — the trajectory as a whole is still
/// bitwise-identical to an uninterrupted run of the same spec.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub summary: RunSummary,
    pub losses: Vec<f64>,
    /// The job's own tracked peak (child tracker, isolated).
    pub session_peak: u64,
}

/// Outcome of one job, success or failure.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job: Job,
    /// Predicted peak bytes the admission gate reserved.
    pub cost_bytes: u64,
    /// Seconds spent queued behind the budget (summed over re-admissions).
    pub wait_secs: f64,
    /// Seconds from admission to completion (summed over run segments).
    pub run_secs: f64,
    /// Worker that ran the job's final segment.
    pub worker: usize,
    /// Times this job was preempted (snapshotted + requeued).
    pub preempts: u32,
    /// Times this job successfully resumed from a snapshot.
    pub resumes: u32,
    pub result: Result<JobResult, String>,
}

/// Per-method occupancy summary for the report.
#[derive(Debug, Clone, Default)]
pub struct MethodStats {
    pub jobs: usize,
    /// Largest single-job predicted cost for the method.
    pub cost_bytes: u64,
    /// Most jobs of this method admitted at once.
    pub peak_concurrent: usize,
    pub total_steps: usize,
}

/// Everything a fleet run produced.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Initial budget (the schedule may have changed it since).
    pub budget_bytes: u64,
    /// Budget in force when the fleet finished.
    pub final_budget_bytes: u64,
    pub workers: usize,
    /// Outcomes in job-id order.
    pub outcomes: Vec<JobOutcome>,
    pub wall_secs: f64,
    /// Fleet-wide aggregate tracked peak (sum of live bytes across all
    /// concurrent sessions at the worst moment).
    pub aggregate_peak: u64,
    /// High-water mark of the admission gate's committed (predicted) bytes.
    pub peak_committed: u64,
    /// Most jobs admitted at once, across methods.
    pub peak_concurrent: usize,
    /// Total preemptions (sessions parked to disk).
    pub preempts: usize,
    /// Total successful resumes from parked snapshots.
    pub resumes: usize,
    /// High-water mark of parked snapshot bytes (`snapshot` tag).
    pub snapshot_peak_bytes: u64,
    /// High-water mark of shared frozen-weight bytes resident at once
    /// (`weights:shared` tag on the fleet weight cache).
    pub shared_weight_peak_bytes: u64,
    /// Admissions that attached to an already-resident weight class —
    /// jobs that paid ZERO weight bytes because another admitted job
    /// already held their frozen base.
    pub weight_shared_admissions: usize,
    pub per_method: BTreeMap<String, MethodStats>,
    /// The fleet-wide metrics registry every job recorded into: step
    /// counts/latencies per job plus the `fleet/*` lifecycle counters the
    /// headline numbers above are views of.
    pub registry: MetricsRegistry,
}

impl FleetReport {
    pub fn completed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.result.is_ok()).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.len() - self.completed()
    }

    pub fn jobs_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.completed() as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Render the fleet report: headline occupancy numbers, the
    /// preemption tally, the per-method concurrency table (the
    /// MeSP-vs-MeBP demo), and per-job rows.
    pub fn render(&self) -> String {
        let mut out = String::from("## fleet report\n\n");
        out.push_str(&format!(
            "jobs: {} completed, {} failed | wall {:.2}s | {:.2} jobs/s | \
             {} workers\n",
            self.completed(),
            self.failed(),
            self.wall_secs,
            self.jobs_per_sec(),
            self.workers
        ));
        out.push_str(&format!(
            "budget {} MB | predicted occupancy peak {} MB | aggregate \
             tracked peak {} MB | peak concurrent jobs {}\n",
            fmt_mb(self.budget_bytes),
            fmt_mb(self.peak_committed),
            fmt_mb(self.aggregate_peak),
            self.peak_concurrent
        ));
        out.push_str(&format!(
            "preempts {} | resumes {} | parked snapshot peak {} MB | \
             final budget {} MB\n",
            self.preempts,
            self.resumes,
            fmt_mb(self.snapshot_peak_bytes),
            fmt_mb(self.final_budget_bytes)
        ));
        out.push_str(&format!(
            "shared weights peak {} MB | {} shared-weight attaches\n\n",
            fmt_mb(self.shared_weight_peak_bytes),
            self.weight_shared_admissions
        ));

        let mut t = TableBuilder::new(&[
            "Method", "Jobs", "Cost MB/job", "Max concurrent", "Steps",
        ]);
        for (name, m) in &self.per_method {
            t.row(vec![
                name.clone(),
                m.jobs.to_string(),
                fmt_mb(m.cost_bytes),
                m.peak_concurrent.to_string(),
                m.total_steps.to_string(),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');

        let mut t = TableBuilder::new(&[
            "Job", "Pri", "Method", "Config", "Steps", "Pre", "Wait s",
            "Run s", "Final loss", "Peak MB", "Status",
        ]);
        for o in &self.outcomes {
            let (loss, peak, status) = match &o.result {
                Ok(r) => (
                    format!("{:.4}", r.summary.final_loss),
                    fmt_mb(r.session_peak),
                    if r.summary.healthy() { "ok" } else { "DIVERGED" }
                        .to_string(),
                ),
                Err(e) => ("-".into(), "-".into(), format!("FAILED: {e}")),
            };
            t.row(vec![
                o.job.id.to_string(),
                o.job.spec.priority.to_string(),
                o.job.spec.method.name().into(),
                o.job.spec.config.clone(),
                o.job.spec.steps.to_string(),
                o.preempts.to_string(),
                format!("{:.3}", o.wait_secs),
                format!("{:.3}", o.run_secs),
                loss,
                peak,
                status,
            ]);
        }
        out.push_str(&t.render());
        out
    }
}

/// Kernel threads each concurrently-running session may use so that
/// `workers` sessions together never oversubscribe `cores` (each worker
/// always gets at least one). The scheduler applies this to every job
/// whose config leaves `threads` on auto (0); an explicit `--threads`
/// wins.
pub fn kernel_thread_budget(cores: usize, workers: usize) -> usize {
    (cores / workers.max(1)).max(1)
}

/// A session parked on disk between preemption and resume.
struct Parked {
    path: PathBuf,
    /// Holds the snapshot's byte count under the aggregate `snapshot`
    /// tag while the job is parked; dropped on resume.
    _snapshot_guard: Guard,
}

/// One unit in the scheduler queue: a job plus its suspend/resume
/// baggage (accumulated across preemption cycles).
struct QueueEntry {
    job: Job,
    parked: Option<Parked>,
    preempts: u32,
    resumes: u32,
    wait_secs: f64,
    run_secs: f64,
}

impl QueueEntry {
    fn fresh(job: Job) -> QueueEntry {
        QueueEntry {
            job,
            parked: None,
            preempts: 0,
            resumes: 0,
            wait_secs: 0.0,
            run_secs: 0.0,
        }
    }
}

struct QueueState {
    entries: VecDeque<QueueEntry>,
    done: usize,
    total: usize,
}

/// Fleet-wide step counter driving the budget schedule. Shared with the
/// serve daemon (`fleet::serve`), whose sim and real steps both bump it.
pub(crate) struct Progress {
    steps: AtomicU64,
    schedule: Vec<BudgetChange>,
    next: Mutex<usize>,
}

impl Progress {
    pub(crate) fn new(schedule: Vec<BudgetChange>) -> Progress {
        Progress {
            steps: AtomicU64::new(0),
            schedule,
            next: Mutex::new(0),
        }
    }

    /// Total optimization steps completed fleet-wide so far.
    pub(crate) fn total(&self) -> u64 {
        self.steps.load(Ordering::SeqCst)
    }

    /// Record one completed optimization step; apply every schedule
    /// point the new total has crossed. Each application also lowers
    /// the refusal ceiling to the max of the new budget and every
    /// still-pending point, so a transient dip parks jobs (they wait
    /// for the growth the schedule promises) while a permanent shrink
    /// below a job's cost eventually refuses it honestly.
    pub(crate) fn bump(&self, admission: &Admission) {
        let total = self.steps.fetch_add(1, Ordering::SeqCst) + 1;
        if self.schedule.is_empty() {
            return;
        }
        let mut next = self.next.lock().unwrap();
        while *next < self.schedule.len()
            && self.schedule[*next].at_step <= total
        {
            let budget = self.schedule[*next].budget_bytes;
            let ceiling = self.schedule[*next + 1..]
                .iter()
                .map(|c| c.budget_bytes)
                .max()
                .unwrap_or(0)
                .max(budget);
            admission.set_budget_with_ceiling(budget, ceiling);
            *next += 1;
        }
    }
}

enum RunOutcome {
    Done(JobOutcome),
    Parked(QueueEntry),
}

/// The scheduler entry point (stateless; all state lives per-run).
pub struct Scheduler;

impl Scheduler {
    /// Run `jobs` on a worker pool under `opts.budget_bytes`. Per-job
    /// failures are captured in the report (the fleet keeps going);
    /// errors constructing the fleet itself are returned.
    pub fn run(
        opts: &FleetOptions,
        base: &TrainConfig,
        jobs: Vec<Job>,
    ) -> anyhow::Result<FleetReport> {
        anyhow::ensure!(!jobs.is_empty(), "fleet has no jobs");
        anyhow::ensure!(opts.budget_bytes > 0, "fleet budget must be positive");
        let workers = opts.workers.clamp(1, jobs.len());
        let n_jobs = jobs.len();
        let preempt_enabled = opts.preempt || !opts.budget_schedule.is_empty();

        // Arrival tickets need the queue to hold ids 0..n IN ORDER (what
        // grid / load_jobs / sweep_methods produce): a worker blocked on
        // ticket k must never sit in front of the unpopped job that
        // would advance the ticket. Hand-built out-of-order job lists
        // fall back to un-ticketed admission.
        let ticketed = jobs.iter().enumerate().all(|(i, j)| j.id == i);

        let snap_dir = opts.snapshot_dir.clone().unwrap_or_else(|| {
            std::env::temp_dir()
                .join(format!("mesp-fleet-{}", std::process::id()))
        });
        if preempt_enabled {
            std::fs::create_dir_all(&snap_dir).map_err(|e| {
                anyhow::anyhow!("create snapshot dir {}: {e}", snap_dir.display())
            })?;
        }

        let admission = Admission::new(opts.budget_bytes);
        // The refusal ceiling spans the whole schedule: a job that fits
        // any still-reachable budget waits/parks through dips instead of
        // being refused permanently.
        let ceiling = opts
            .budget_schedule
            .iter()
            .map(|c| c.budget_bytes)
            .max()
            .unwrap_or(0)
            .max(opts.budget_bytes);
        admission.set_budget_with_ceiling(opts.budget_bytes, ceiling);
        if preempt_enabled {
            admission.enable_preemption();
        }
        let progress = Progress::new(opts.budget_schedule.clone());
        let aggregate = MemoryTracker::new();
        // One weight cache per fleet run: every session of this run
        // interns its frozen base here, so same-base jobs share one
        // copy — charged once, on a child of the aggregate, under
        // `weights:shared`.
        let weight_cache = WeightCache::new(aggregate.child());
        // One shared trace sink + metrics registry for the whole fleet:
        // jobs record through job-scoped handles so a single Perfetto
        // timeline shows every worker, and the lifecycle counters below
        // aggregate across jobs.
        let trace = if opts.trace_path.is_some() {
            TraceSink::enabled()
        } else {
            TraceSink::disabled()
        };
        let registry = MetricsRegistry::new();
        let queue = Mutex::new(QueueState {
            entries: jobs.into_iter().map(QueueEntry::fresh).collect(),
            done: 0,
            total: n_jobs,
        });
        let qcv = Condvar::new();
        let results: Mutex<Vec<JobOutcome>> =
            Mutex::new(Vec::with_capacity(n_jobs));

        let start = Instant::now();
        std::thread::scope(|s| {
            for w in 0..workers {
                let (queue, qcv, results) = (&queue, &qcv, &results);
                let (admission, aggregate, progress) =
                    (&admission, &aggregate, &progress);
                let (snap_dir, weight_cache) = (&snap_dir, &weight_cache);
                let (trace, registry) = (&trace, &registry);
                s.spawn(move || loop {
                    // Pop the next queue entry; a parked entry or a fresh
                    // job alike. Wait while the queue is empty but jobs
                    // are still running (they may park and come back).
                    let entry = {
                        let mut q = queue.lock().unwrap();
                        loop {
                            if let Some(e) = q.entries.pop_front() {
                                break Some(e);
                            }
                            if q.done >= q.total {
                                break None;
                            }
                            q = qcv.wait(q).unwrap();
                        }
                    };
                    let Some(entry) = entry else { break };
                    match run_job(
                        w, workers, entry, admission, aggregate, weight_cache,
                        base, snap_dir, preempt_enabled, ticketed, progress,
                        trace, registry,
                    ) {
                        RunOutcome::Done(outcome) => {
                            results.lock().unwrap().push(outcome);
                            queue.lock().unwrap().done += 1;
                            qcv.notify_all();
                        }
                        RunOutcome::Parked(entry) => {
                            queue.lock().unwrap().entries.push_back(entry);
                            qcv.notify_all();
                        }
                    }
                });
            }
        });
        let wall_secs = start.elapsed().as_secs_f64();

        let mut outcomes = results.into_inner().unwrap();
        outcomes.sort_by_key(|o| o.job.id);

        let mut per_method: BTreeMap<String, MethodStats> = BTreeMap::new();
        for o in &outcomes {
            let m = per_method
                .entry(o.job.spec.method.name().to_string())
                .or_default();
            m.jobs += 1;
            m.cost_bytes = m.cost_bytes.max(o.cost_bytes);
            if let Ok(r) = &o.result {
                m.total_steps += r.summary.steps;
            }
        }
        let adm_stats = admission.stats();
        for (name, peak) in &adm_stats.peak_by_method {
            if let Some(m) = per_method.get_mut(name) {
                m.peak_concurrent = *peak;
            }
        }

        // Fold the fleet-wide occupancy numbers into the registry so the
        // JSONL export is self-contained, then write the exports the
        // options ask for. The report's preempt/resume tallies are READ
        // from the registry — the counters run_job bumped are the single
        // source of truth (they match the per-outcome sums by
        // construction).
        registry.gauge_set("fleet/aggregate_peak_bytes", aggregate.peak() as f64);
        registry
            .gauge_set("fleet/peak_committed_bytes", adm_stats.peak_committed as f64);
        registry
            .gauge_set("fleet/peak_concurrent_jobs", adm_stats.peak_concurrent as f64);
        registry.gauge_set(
            "fleet/snapshot_peak_bytes",
            aggregate.tag_peak("snapshot") as f64,
        );
        registry.gauge_set("fleet/wall_secs", wall_secs);
        if let Some(p) = &opts.trace_path {
            trace.export_chrome(p)?;
        }
        if let Some(p) = &opts.metrics_out {
            registry.export_jsonl(p)?;
        }

        Ok(FleetReport {
            budget_bytes: opts.budget_bytes,
            final_budget_bytes: admission.budget(),
            workers,
            preempts: registry.counter("fleet/preempts") as usize,
            resumes: registry.counter("fleet/resumes") as usize,
            snapshot_peak_bytes: aggregate.tag_peak("snapshot"),
            shared_weight_peak_bytes: weight_cache
                .tracker()
                .tag_peak("weights:shared"),
            weight_shared_admissions: adm_stats.weight_shared_admissions,
            outcomes,
            wall_secs,
            aggregate_peak: aggregate.peak(),
            peak_committed: adm_stats.peak_committed,
            peak_concurrent: adm_stats.peak_concurrent,
            per_method,
            registry,
        })
    }
}

/// Cost → admit (blocking) → run one session step-by-step on a child
/// tracker, polling the permit for preemption between steps. A parked
/// session is snapshotted and its entry returned for requeueing; the
/// session is dropped (all its tracked bytes released) BEFORE the permit
/// returns the reservation, so the budget always covers live sessions.
#[allow(clippy::too_many_arguments)] // one call site; a worker's full wiring
fn run_job(
    worker: usize,
    workers: usize,
    mut entry: QueueEntry,
    admission: &Admission,
    aggregate: &MemoryTracker,
    weight_cache: &WeightCache,
    base: &TrainConfig,
    snap_dir: &Path,
    preempt_enabled: bool,
    ticketed: bool,
    progress: &Progress,
    trace: &TraceSink,
    registry: &MetricsRegistry,
) -> RunOutcome {
    let job = entry.job.clone();
    // Job-scoped handle: every event this job emits (down to per-GEMM
    // spans inside its session) carries the job id.
    let jtrace = trace.for_job(job.id as u64);
    let fail = |entry: &QueueEntry, cost_bytes: u64, msg: String| {
        RunOutcome::Done(JobOutcome {
            job: entry.job.clone(),
            cost_bytes,
            wait_secs: entry.wait_secs,
            run_secs: entry.run_secs,
            worker,
            preempts: entry.preempts,
            resumes: entry.resumes,
            result: Err(msg),
        })
    };

    let cost_bytes = match job_cost_bytes(&job.spec) {
        Ok(c) => c,
        Err(e) => return fail(&entry, 0, format!("costing failed: {e:#}")),
    };
    // The frozen base is charged per CLASS, not per job: the first
    // admitted holder of (config, model seed, quant) reserves the
    // resident bytes, later same-class jobs attach for free, the last
    // release returns them — mirroring the weight cache's one shared
    // `FrozenModel` per class.
    let wclass = match job_weight_class(&job.spec) {
        Ok(w) => w,
        Err(e) => return fail(&entry, 0, format!("costing failed: {e:#}")),
    };

    // Initial admissions carry their job id as an arrival ticket (granted
    // strictly in id order — determinism for the preemption tests);
    // resumed jobs re-enter whenever the budget next has room.
    let ticket = (ticketed && entry.parked.is_none()).then_some(job.id);
    let queued = Instant::now();
    let permit = match admission.admit_job_shared(
        job.spec.method,
        cost_bytes,
        job.spec.priority,
        ticket,
        Some(wclass),
    ) {
        Ok(p) => p,
        Err(e) => {
            entry.wait_secs += queued.elapsed().as_secs_f64();
            return fail(&entry, cost_bytes, format!("{e:#}"));
        }
    };
    let waited = queued.elapsed().as_secs_f64();
    entry.wait_secs += waited;
    registry.observe("fleet/admission_wait_s", waited);
    jtrace.instant(
        "admit",
        "fleet",
        vec![("cost_bytes", Json::Num(cost_bytes as f64))],
    );

    let started = Instant::now();
    let mut cfg = job.spec.to_train_config(base);
    if cfg.threads == 0 {
        // Budget kernel threads against the worker pool so `workers`
        // concurrent sessions don't oversubscribe the machine.
        cfg.threads =
            kernel_thread_budget(crate::runtime::kernels::auto_threads(), workers);
    }
    let target = cfg.steps;

    let mut builder = TrainSession::builder(cfg)
        .tracker(aggregate.child())
        .weight_cache(weight_cache.clone())
        .trace(jtrace.clone())
        .registry(registry.clone());
    if let Some(p) = &entry.parked {
        builder = builder.resume_from(&p.path);
    }
    let mut sess = match builder.build() {
        Ok(s) => s,
        Err(e) => {
            entry.run_secs += started.elapsed().as_secs_f64();
            drop(permit);
            return fail(&entry, cost_bytes, format!("{e:#}"));
        }
    };
    if let Some(p) = entry.parked.take() {
        entry.resumes += 1;
        registry.counter_add("fleet/resumes", 1);
        jtrace.instant(
            "resume",
            "fleet",
            vec![("step", Json::Num(sess.steps_done() as f64))],
        );
        let _ = std::fs::remove_file(&p.path);
        // p drops here: the `snapshot` tag bytes return to the aggregate.
    }

    // Step until done or asked to yield.
    let mut park = false;
    let result = (|| -> anyhow::Result<Option<JobResult>> {
        while sess.steps_done() < target {
            if preempt_enabled && permit.preempt_requested() {
                return Ok(None);
            }
            sess.step_once()?;
            progress.bump(admission);
        }
        let summary = sess.metrics.summary();
        let losses = sess.losses();
        // max per-step tracked peak (the engines reset the peak at step
        // boundaries, so the raw tracker only remembers the last step)
        let session_peak = summary.peak_bytes;
        Ok(Some(JobResult { summary, losses, session_peak }))
    })();
    entry.run_secs += started.elapsed().as_secs_f64();

    let parked = match result {
        Ok(Some(jr)) => {
            jtrace.instant(
                "done",
                "fleet",
                vec![("steps", Json::Num(sess.steps_done() as f64))],
            );
            drop(sess);
            // `sess` dropped: every tracked byte of the job is released
            // from the aggregate before the permit frees the budget.
            drop(permit);
            return RunOutcome::Done(JobOutcome {
                job,
                cost_bytes,
                wait_secs: entry.wait_secs,
                run_secs: entry.run_secs,
                worker,
                preempts: entry.preempts,
                resumes: entry.resumes,
                result: Ok(jr),
            });
        }
        Ok(None) => {
            park = true;
            let path = snap_dir
                .join(format!("job-{}-step-{}.snap", job.id, sess.steps_done()));
            sess.save_snapshot(&path).map(|bytes| (path, bytes))
        }
        Err(e) => Err(e),
    };

    match parked {
        Ok((path, bytes)) => {
            jtrace.instant(
                "park",
                "fleet",
                vec![
                    ("step", Json::Num(sess.steps_done() as f64)),
                    ("snapshot_bytes", Json::Num(bytes as f64)),
                ],
            );
            drop(sess);
            let guard = aggregate.track("snapshot", bytes);
            drop(permit);
            entry.preempts += 1;
            registry.counter_add("fleet/preempts", 1);
            entry.parked = Some(Parked { path, _snapshot_guard: guard });
            RunOutcome::Parked(entry)
        }
        Err(e) => {
            jtrace.instant("fail", "fleet", vec![]);
            drop(sess);
            drop(permit);
            let what = if park { "snapshot failed: " } else { "" };
            fail(&entry, cost_bytes, format!("{what}{e:#}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_budget_divides_cores_without_oversubscribing() {
        assert_eq!(kernel_thread_budget(8, 4), 2);
        assert_eq!(kernel_thread_budget(8, 3), 2);
        assert_eq!(kernel_thread_budget(2, 4), 1, "never below one thread");
        assert_eq!(kernel_thread_budget(16, 1), 16);
        assert_eq!(kernel_thread_budget(4, 0), 4, "0 workers treated as 1");
        for (cores, workers) in [(2, 2), (4, 3), (16, 5), (64, 9)] {
            let per = kernel_thread_budget(cores, workers);
            assert!(per * workers <= cores.max(workers),
                    "{workers}x{per} threads oversubscribe {cores} cores");
        }
    }

    #[test]
    fn budget_schedule_parses_and_validates() {
        let s = parse_budget_schedule("20:48,50:24").unwrap();
        assert_eq!(
            s,
            vec![
                BudgetChange { at_step: 20, budget_bytes: 48 << 20 },
                BudgetChange { at_step: 50, budget_bytes: 24 << 20 },
            ]
        );
        assert_eq!(parse_budget_schedule(" 5:1 ").unwrap().len(), 1);
        for bad in ["", "20", "20:", ":48", "x:48", "20:y", "20:0",
                    "50:24,20:48", "20:48,20:24"] {
            assert!(parse_budget_schedule(bad).is_err(), "must reject '{bad}'");
        }
    }
}
