//! The fleet scheduler: a worker pool draining a job queue behind the
//! admission gate.
//!
//! Each worker pops a job, costs it, blocks until the budget admits it,
//! then runs a full [`TrainSession`] on a per-job child of the fleet-wide
//! aggregate [`MemoryTracker`]. The session's tracked bytes therefore
//! roll up into one aggregate whose peak is the fleet's true concurrent
//! high-water mark — the number the report compares against the budget.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;
use std::time::Instant;

use crate::config::TrainConfig;
use crate::coordinator::TrainSession;
use crate::memory::MemoryTracker;
use crate::metrics::{RunSummary, TableBuilder};
use crate::util::stats::fmt_mb;

use super::admission::{job_cost_bytes, Admission};
use super::job::Job;

/// Fleet-wide knobs (the job list and base `TrainConfig` ride separately).
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Shared device budget in bytes: the sum of predicted peak memory of
    /// all concurrently-admitted jobs stays under this.
    pub budget_bytes: u64,
    /// Worker threads draining the queue (clamped to the job count).
    pub workers: usize,
}

/// What one finished job produced.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub summary: RunSummary,
    pub losses: Vec<f64>,
    /// The job's own tracked peak (child tracker, isolated).
    pub session_peak: u64,
}

/// Outcome of one job, success or failure.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job: Job,
    /// Predicted peak bytes the admission gate reserved.
    pub cost_bytes: u64,
    /// Seconds spent queued behind the budget.
    pub wait_secs: f64,
    /// Seconds from admission to completion.
    pub run_secs: f64,
    pub worker: usize,
    pub result: Result<JobResult, String>,
}

/// Per-method occupancy summary for the report.
#[derive(Debug, Clone, Default)]
pub struct MethodStats {
    pub jobs: usize,
    /// Largest single-job predicted cost for the method.
    pub cost_bytes: u64,
    /// Most jobs of this method admitted at once.
    pub peak_concurrent: usize,
    pub total_steps: usize,
}

/// Everything a fleet run produced.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub budget_bytes: u64,
    pub workers: usize,
    /// Outcomes in job-id order.
    pub outcomes: Vec<JobOutcome>,
    pub wall_secs: f64,
    /// Fleet-wide aggregate tracked peak (sum of live bytes across all
    /// concurrent sessions at the worst moment).
    pub aggregate_peak: u64,
    /// High-water mark of the admission gate's committed (predicted) bytes.
    pub peak_committed: u64,
    /// Most jobs admitted at once, across methods.
    pub peak_concurrent: usize,
    pub per_method: BTreeMap<String, MethodStats>,
}

impl FleetReport {
    pub fn completed(&self) -> usize {
        self.outcomes.iter().filter(|o| o.result.is_ok()).count()
    }

    pub fn failed(&self) -> usize {
        self.outcomes.len() - self.completed()
    }

    pub fn jobs_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.completed() as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Render the fleet report: headline occupancy numbers, the
    /// per-method concurrency table (the MeSP-vs-MeBP demo), and per-job
    /// rows.
    pub fn render(&self) -> String {
        let mut out = String::from("## fleet report\n\n");
        out.push_str(&format!(
            "jobs: {} completed, {} failed | wall {:.2}s | {:.2} jobs/s | \
             {} workers\n",
            self.completed(),
            self.failed(),
            self.wall_secs,
            self.jobs_per_sec(),
            self.workers
        ));
        out.push_str(&format!(
            "budget {} MB | predicted occupancy peak {} MB | aggregate \
             tracked peak {} MB | peak concurrent jobs {}\n\n",
            fmt_mb(self.budget_bytes),
            fmt_mb(self.peak_committed),
            fmt_mb(self.aggregate_peak),
            self.peak_concurrent
        ));

        let mut t = TableBuilder::new(&[
            "Method", "Jobs", "Cost MB/job", "Max concurrent", "Steps",
        ]);
        for (name, m) in &self.per_method {
            t.row(vec![
                name.clone(),
                m.jobs.to_string(),
                fmt_mb(m.cost_bytes),
                m.peak_concurrent.to_string(),
                m.total_steps.to_string(),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');

        let mut t = TableBuilder::new(&[
            "Job", "Method", "Config", "Steps", "Wait s", "Run s",
            "Final loss", "Peak MB", "Status",
        ]);
        for o in &self.outcomes {
            let (loss, peak, status) = match &o.result {
                Ok(r) => (
                    format!("{:.4}", r.summary.final_loss),
                    fmt_mb(r.session_peak),
                    if r.summary.healthy() { "ok" } else { "DIVERGED" }
                        .to_string(),
                ),
                Err(e) => ("-".into(), "-".into(), format!("FAILED: {e}")),
            };
            t.row(vec![
                o.job.id.to_string(),
                o.job.spec.method.name().into(),
                o.job.spec.config.clone(),
                o.job.spec.steps.to_string(),
                format!("{:.3}", o.wait_secs),
                format!("{:.3}", o.run_secs),
                loss,
                peak,
                status,
            ]);
        }
        out.push_str(&t.render());
        out
    }
}

/// Kernel threads each concurrently-running session may use so that
/// `workers` sessions together never oversubscribe `cores` (each worker
/// always gets at least one). The scheduler applies this to every job
/// whose config leaves `threads` on auto (0); an explicit `--threads`
/// wins.
pub fn kernel_thread_budget(cores: usize, workers: usize) -> usize {
    (cores / workers.max(1)).max(1)
}

/// The scheduler entry point (stateless; all state lives per-run).
pub struct Scheduler;

impl Scheduler {
    /// Run `jobs` on a worker pool under `opts.budget_bytes`. Per-job
    /// failures are captured in the report (the fleet keeps going);
    /// errors constructing the fleet itself are returned.
    pub fn run(
        opts: &FleetOptions,
        base: &TrainConfig,
        jobs: Vec<Job>,
    ) -> anyhow::Result<FleetReport> {
        anyhow::ensure!(!jobs.is_empty(), "fleet has no jobs");
        anyhow::ensure!(opts.budget_bytes > 0, "fleet budget must be positive");
        let workers = opts.workers.clamp(1, jobs.len());
        let n_jobs = jobs.len();

        let admission = Admission::new(opts.budget_bytes);
        let aggregate = MemoryTracker::new();
        let queue: Mutex<VecDeque<Job>> = Mutex::new(jobs.into());
        let results: Mutex<Vec<JobOutcome>> = Mutex::new(Vec::with_capacity(n_jobs));

        let start = Instant::now();
        std::thread::scope(|s| {
            for w in 0..workers {
                let (queue, results) = (&queue, &results);
                let (admission, aggregate) = (&admission, &aggregate);
                s.spawn(move || loop {
                    let job = queue.lock().unwrap().pop_front();
                    let Some(job) = job else { break };
                    let outcome =
                        run_job(w, workers, job, admission, aggregate, base);
                    results.lock().unwrap().push(outcome);
                });
            }
        });
        let wall_secs = start.elapsed().as_secs_f64();

        let mut outcomes = results.into_inner().unwrap();
        outcomes.sort_by_key(|o| o.job.id);

        let mut per_method: BTreeMap<String, MethodStats> = BTreeMap::new();
        for o in &outcomes {
            let m = per_method
                .entry(o.job.spec.method.name().to_string())
                .or_default();
            m.jobs += 1;
            m.cost_bytes = m.cost_bytes.max(o.cost_bytes);
            if let Ok(r) = &o.result {
                m.total_steps += r.summary.steps;
            }
        }
        let adm_stats = admission.stats();
        for (name, peak) in &adm_stats.peak_by_method {
            if let Some(m) = per_method.get_mut(name) {
                m.peak_concurrent = *peak;
            }
        }

        Ok(FleetReport {
            budget_bytes: opts.budget_bytes,
            workers,
            outcomes,
            wall_secs,
            aggregate_peak: aggregate.peak(),
            peak_committed: adm_stats.peak_committed,
            peak_concurrent: adm_stats.peak_concurrent,
            per_method,
        })
    }
}

/// Cost → admit (blocking) → run one session on a child tracker. The
/// session is dropped (all its tracked bytes released) BEFORE the permit
/// returns the reservation, so the budget always covers live sessions.
fn run_job(
    worker: usize,
    workers: usize,
    job: Job,
    admission: &Admission,
    aggregate: &MemoryTracker,
    base: &TrainConfig,
) -> JobOutcome {
    let cost_bytes = match job_cost_bytes(&job.spec) {
        Ok(c) => c,
        Err(e) => {
            return JobOutcome {
                job,
                cost_bytes: 0,
                wait_secs: 0.0,
                run_secs: 0.0,
                worker,
                result: Err(format!("costing failed: {e:#}")),
            }
        }
    };

    let queued = Instant::now();
    let permit = match admission.admit(job.spec.method, cost_bytes) {
        Ok(p) => p,
        Err(e) => {
            return JobOutcome {
                job,
                cost_bytes,
                wait_secs: queued.elapsed().as_secs_f64(),
                run_secs: 0.0,
                worker,
                result: Err(format!("{e:#}")),
            }
        }
    };
    let wait_secs = queued.elapsed().as_secs_f64();

    let started = Instant::now();
    let result = (|| -> anyhow::Result<JobResult> {
        let mut cfg = job.spec.to_train_config(base);
        if cfg.threads == 0 {
            // Budget kernel threads against the worker pool so `workers`
            // concurrent sessions don't oversubscribe the machine.
            cfg.threads =
                kernel_thread_budget(crate::runtime::kernels::auto_threads(), workers);
        }
        let steps = cfg.steps;
        let mut sess = TrainSession::with_tracker(cfg, aggregate.child())?;
        let summary = sess.run(steps)?;
        let losses = sess.losses();
        // max per-step tracked peak (the engines reset the peak at step
        // boundaries, so the raw tracker only remembers the last step)
        let session_peak = summary.peak_bytes;
        Ok(JobResult { summary, losses, session_peak })
        // `sess` drops here: every tracked byte of the job is released
        // from the aggregate before the permit below frees the budget.
    })();
    let run_secs = started.elapsed().as_secs_f64();
    drop(permit);

    JobOutcome {
        job,
        cost_bytes,
        wait_secs,
        run_secs,
        worker,
        result: result.map_err(|e| format!("{e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_budget_divides_cores_without_oversubscribing() {
        assert_eq!(kernel_thread_budget(8, 4), 2);
        assert_eq!(kernel_thread_budget(8, 3), 2);
        assert_eq!(kernel_thread_budget(2, 4), 1, "never below one thread");
        assert_eq!(kernel_thread_budget(16, 1), 16);
        assert_eq!(kernel_thread_budget(4, 0), 4, "0 workers treated as 1");
        for (cores, workers) in [(2, 2), (4, 3), (16, 5), (64, 9)] {
            let per = kernel_thread_budget(cores, workers);
            assert!(per * workers <= cores.max(workers),
                    "{workers}x{per} threads oversubscribe {cores} cores");
        }
    }
}
