//! Fleet scheduling: concurrent multi-session fine-tuning under a shared
//! device memory budget.
//!
//! Mobile devices give ALL workloads a combined 6–12 GB; MeSP's peak-
//! memory reduction matters exactly because it lets fine-tuning coexist
//! with everything else. This subsystem turns that argument into a
//! serving path: a job queue ([`job`]), an admission gate that costs each
//! job with the analytical peak-memory model before it starts
//! ([`admission`]), and a worker-pool scheduler that runs admitted jobs
//! as real concurrent [`crate::coordinator::TrainSession`]s, each on a
//! child of one fleet-wide aggregate [`crate::memory::MemoryTracker`]
//! ([`scheduler`]).
//!
//! The visible consequence of the paper's claim: under the same budget,
//! the gate admits roughly twice as many concurrent MeSP sessions as
//! MeBP sessions (`cargo run --release -- fleet --config toy
//! --budget-mb 64 --jobs 8`, or `examples/fleet_demo.rs`).

pub mod admission;
pub mod job;
pub mod scheduler;

pub use admission::{job_cost_bytes, Admission, AdmissionStats, Permit};
pub use job::{grid, load_jobs, Job, JobSpec};
pub use scheduler::{
    FleetOptions, FleetReport, JobOutcome, JobResult, MethodStats, Scheduler,
};
