//! Fleet scheduling: concurrent multi-session fine-tuning under a shared
//! device memory budget.
//!
//! Mobile devices give ALL workloads a combined 6–12 GB; MeSP's peak-
//! memory reduction matters exactly because it lets fine-tuning coexist
//! with everything else. This subsystem turns that argument into a
//! serving path: a job queue ([`job`]), an admission gate that costs each
//! job with the analytical peak-memory model before it starts
//! ([`admission`]), and a worker-pool scheduler that runs admitted jobs
//! as real concurrent [`crate::coordinator::TrainSession`]s, each on a
//! child of one fleet-wide aggregate [`crate::memory::MemoryTracker`]
//! ([`scheduler`]).
//!
//! The visible consequence of the paper's claim: under the same budget,
//! the gate admits roughly twice as many concurrent MeSP sessions as
//! MeBP sessions (`cargo run --release -- fleet --config toy
//! --budget-mb 64 --jobs 8`, or `examples/fleet_demo.rs`).
//!
//! Since jobs carry a `priority` and sessions are snapshot-resumable
//! ([`crate::persist`]), the scheduler also handles a SHRINKING budget:
//! `--budget-schedule` (or an arriving higher-priority job) preempts the
//! lowest-priority running job to disk and resumes it — bitwise — when
//! the budget has room again. The fleet is a long-lived service, not a
//! batch runner: a squeeze parks work instead of killing it.
//!
//! Same-base jobs additionally share ONE resident copy of their frozen
//! base weights through a fleet-wide [`crate::model::WeightCache`]:
//! admission charges each weight class ([`admission::WeightClass`]) once
//! across all its holders, so a budget sized for two private-weight jobs
//! overlaps many shared-weight ones.
//!
//! On top of the batch scheduler sits the daemon form: [`serve`] accepts
//! jobs over a Unix socket for as long as it lives (JSONL protocol in
//! [`protocol`], per-tenant quotas and weighted-fair dispatch, crash
//! recovery from `--snapshot-dir`), and [`loadgen`] replays synthetic
//! arrival traces against it to benchmark the serving path end to end.
//! `docs/serving.md` is the operator-facing specification.

pub mod admission;
pub mod job;
pub mod loadgen;
pub mod protocol;
pub mod scheduler;
pub mod serve;

pub use admission::{
    job_cost_bytes, job_weight_class, Admission, AdmissionStats, Permit,
    WeightClass,
};
pub use job::{grid, load_jobs, Job, JobSpec, MAX_PRIORITY};
pub use loadgen::{LoadgenOptions, LoadgenReport};
pub use scheduler::{
    parse_budget_schedule, BudgetChange, FleetOptions, FleetReport, JobOutcome,
    JobResult, MethodStats, Scheduler,
};
pub use serve::{
    ServeOptions, ServeSummary, Server, EXIT_JOB_FAILURES, EXIT_OK,
    EXIT_RUNTIME, EXIT_STARTUP,
};
