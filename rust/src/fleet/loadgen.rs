//! `mesp loadgen`: trace-driven load generation against a live `mesp
//! serve` daemon.
//!
//! The generator synthesizes a deterministic arrival trace from a seed —
//! Poisson inter-arrivals whose rate is modulated by a diurnal sine wave
//! and periodic bursts — and replays it over the daemon's Unix socket:
//! hundreds of thousands of submits flowing through the REAL protocol
//! parser, admission gate, tenant quotas and WFQ dispatch. Mid-run
//! budget squeezes (`--squeeze idx:mb,...`) exercise the
//! preempt-to-disk path under load.
//!
//! Jobs are submitted as `sim` jobs by default (real admission costs,
//! virtual step loops) so a 100k-arrival replay finishes in minutes;
//! `--real` switches to full training sessions for small traces.
//!
//! The run report — throughput, latency percentiles from the daemon's
//! own histogram, preempt churn, per-tenant fairness — is written as
//! `BENCH_serve.json` (same convention as the other `BENCH_*.json`
//! artifacts CI uploads).

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::protocol::{self, Response, PROTOCOL_VERSION};

/// Everything `mesp loadgen` is configured with.
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Daemon socket to replay against.
    pub socket: PathBuf,
    /// Total arrivals to generate.
    pub arrivals: usize,
    /// Mean arrival rate in jobs per second of TRACE time.
    pub rate: f64,
    /// Number of synthetic tenants (`t0`, `t1`, …).
    pub tenants: usize,
    /// Per-step virtual latency of submitted sim jobs, microseconds.
    pub sim_us: u64,
    /// Trace seed: same seed, same trace, bit for bit.
    pub seed: u64,
    /// Steps per submitted job.
    pub steps: usize,
    /// Replay pacing: 1.0 = real time, 2.0 = twice as fast, 0.0 = flat
    /// out (ignore trace timestamps entirely).
    pub time_scale: f64,
    /// Diurnal modulation amplitude in [0,1): rate(t) swings by ±amp.
    pub diurnal_amp: f64,
    /// Diurnal period in trace seconds.
    pub diurnal_period_s: f64,
    /// Every N arrivals, a burst begins… (0 disables bursts)
    pub burst_every: usize,
    /// …lasting this many arrivals…
    pub burst_len: usize,
    /// …at this rate multiplier.
    pub burst_x: f64,
    /// Budget squeezes: after arrival index N, set the budget to BYTES
    /// (ceiling untouched, so squeezed-out jobs park, not die).
    pub squeezes: Vec<(usize, u64)>,
    /// Submit real training jobs instead of sim jobs.
    pub real: bool,
    /// Send `shutdown` after the trace drains (CI wants the full
    /// lifecycle; interactive runs leave the daemon up).
    pub shutdown: bool,
    /// Where to write the benchmark JSON (default `BENCH_serve.json`).
    pub out: PathBuf,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        LoadgenOptions {
            socket: PathBuf::from("mesp.sock"),
            arrivals: 1000,
            rate: 200.0,
            tenants: 3,
            sim_us: 0,
            seed: 42,
            steps: 4,
            time_scale: 0.0,
            diurnal_amp: 0.5,
            diurnal_period_s: 60.0,
            burst_every: 500,
            burst_len: 50,
            burst_x: 5.0,
            squeezes: Vec::new(),
            real: false,
            shutdown: false,
            out: PathBuf::from("BENCH_serve.json"),
        }
    }
}

/// Parse `--squeeze idx:mb,idx:mb` (budget in MB, applied after the
/// given arrival index; indices strictly ascending).
pub fn parse_squeezes(s: &str) -> anyhow::Result<Vec<(usize, u64)>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        let (idx, mb) = p.split_once(':').ok_or_else(|| {
            anyhow::anyhow!("squeeze entry '{p}' is not idx:mb")
        })?;
        let idx: usize = idx.trim().parse().map_err(|_| {
            anyhow::anyhow!("squeeze index '{idx}' is not an integer")
        })?;
        let mb: u64 = mb.trim().parse().map_err(|_| {
            anyhow::anyhow!("squeeze budget '{mb}' is not an integer (MB)")
        })?;
        anyhow::ensure!(mb > 0, "squeeze budget must be positive MB");
        out.push((idx, mb << 20));
    }
    anyhow::ensure!(!out.is_empty(), "empty squeeze list '{s}'");
    for w in out.windows(2) {
        anyhow::ensure!(
            w[0].0 < w[1].0,
            "squeeze indices must be strictly ascending ({} then {})",
            w[0].0,
            w[1].0
        );
    }
    Ok(out)
}

/// One synthetic arrival.
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    /// Trace-time offset from the start, seconds.
    pub at_s: f64,
    pub tenant: String,
    /// 0..=9; most arrivals are 0, ~10% get a priority bump.
    pub priority: u8,
}

/// Instantaneous rate multiplier at trace time `t` for arrival index
/// `i`: diurnal sine wave times burst factor.
fn rate_factor(opts: &LoadgenOptions, t: f64, i: usize) -> f64 {
    let diurnal = if opts.diurnal_amp > 0.0 && opts.diurnal_period_s > 0.0 {
        1.0 + opts.diurnal_amp
            * (2.0 * std::f64::consts::PI * t / opts.diurnal_period_s).sin()
    } else {
        1.0
    };
    let burst = if opts.burst_every > 0
        && opts.burst_len > 0
        && i % opts.burst_every < opts.burst_len
    {
        opts.burst_x.max(1.0)
    } else {
        1.0
    };
    (diurnal * burst).max(1e-6)
}

/// Synthesize the arrival trace. Deterministic in `opts.seed` (and the
/// shape knobs): the same options always produce the identical trace,
/// so a benchmark regression is a scheduler change, not trace noise.
pub fn synth_trace(opts: &LoadgenOptions) -> Vec<Arrival> {
    let mut rng = Rng::new(opts.seed);
    let mut t = 0.0_f64;
    let mut out = Vec::with_capacity(opts.arrivals);
    for i in 0..opts.arrivals {
        let rate = opts.rate.max(1e-6) * rate_factor(opts, t, i);
        // Exponential inter-arrival: -ln(1-u)/λ (Poisson process).
        let u = rng.uniform() as f64;
        t += -(1.0 - u).max(1e-12).ln() / rate;
        let tenant = format!("t{}", rng.below(opts.tenants.max(1)));
        let priority = if rng.uniform() < 0.1 {
            1 + rng.below(9) as u8
        } else {
            0
        };
        out.push(Arrival { at_s: t, tenant, priority });
    }
    out
}

/// A blocking JSONL client on the daemon socket: one request out, one
/// response in, strictly in order. Shared by the loadgen and the
/// integration tests.
pub struct Client {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
    next_id: u64,
}

impl Client {
    pub fn connect(socket: &Path) -> anyhow::Result<Client> {
        let stream = UnixStream::connect(socket).map_err(|e| {
            anyhow::anyhow!("connect to {}: {e}", socket.display())
        })?;
        let writer = stream.try_clone().map_err(|e| {
            anyhow::anyhow!("clone socket stream: {e}")
        })?;
        Ok(Client { reader: BufReader::new(stream), writer, next_id: 0 })
    }

    /// Send one raw line, read one raw line. The escape hatch for tests
    /// that need to send malformed frames.
    pub fn call_raw(&mut self, line: &str) -> anyhow::Result<String> {
        writeln!(self.writer, "{line}")
            .map_err(|e| anyhow::anyhow!("socket write: {e}"))?;
        let mut resp = String::new();
        let n = self
            .reader
            .read_line(&mut resp)
            .map_err(|e| anyhow::anyhow!("socket read: {e}"))?;
        anyhow::ensure!(n > 0, "daemon closed the connection");
        Ok(resp)
    }

    /// Send a verb with fields, return the parsed response. `fields`
    /// must not contain `v`/`id`/`verb` (they are supplied here).
    pub fn call(
        &mut self,
        verb: &str,
        fields: Vec<(&str, Json)>,
    ) -> anyhow::Result<Response> {
        let id = self.next_id;
        self.next_id += 1;
        let mut pairs = vec![
            ("v", Json::num(PROTOCOL_VERSION as f64)),
            ("id", Json::num(id as f64)),
            ("verb", Json::str(verb)),
        ];
        pairs.extend(fields);
        let line = Json::obj(pairs).to_string();
        let resp = self.call_raw(&line)?;
        let r = protocol::parse_response(&resp)?;
        anyhow::ensure!(
            r.id == Some(id),
            "response id {:?} does not match request id {id}",
            r.id
        );
        Ok(r)
    }
}

/// Per-tenant service observed at the end of the run.
#[derive(Debug, Clone)]
pub struct TenantService {
    pub tenant: String,
    pub weight: u64,
    pub done: u64,
    pub steps: u64,
}

/// Everything one loadgen run measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub arrivals: usize,
    pub accepted: usize,
    /// Rejections by protocol error code.
    pub rejected: Vec<(String, usize)>,
    pub wall_secs: f64,
    pub jobs_done: u64,
    pub jobs_failed: u64,
    /// (count, mean, p50, p90, p99, max) of submit→done seconds, from
    /// the daemon's own histogram.
    pub latency_s: Option<(u64, f64, f64, f64, f64, f64)>,
    pub preempts: u64,
    pub resumes: u64,
    pub fleet_steps: u64,
    pub squeezes_applied: usize,
    pub tenants: Vec<TenantService>,
}

impl LoadgenReport {
    pub fn jobs_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.jobs_done as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Weighted-fairness ratio: max over min of per-tenant
    /// steps-per-weight. 1.0 = perfectly weight-proportional service;
    /// the CI gate allows slack for arrival randomness.
    pub fn fairness_ratio(&self) -> f64 {
        let shares: Vec<f64> = self
            .tenants
            .iter()
            .filter(|t| t.weight > 0)
            .map(|t| t.steps as f64 / t.weight as f64)
            .collect();
        let lo = shares.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = shares.iter().cloned().fold(0.0_f64, f64::max);
        if shares.len() < 2 || lo <= 0.0 {
            1.0
        } else {
            hi / lo
        }
    }

    pub fn to_json(&self) -> Json {
        let latency = match self.latency_s {
            Some((count, mean, p50, p90, p99, max)) => Json::obj(vec![
                ("count", Json::num(count as f64)),
                ("mean", Json::Num(mean)),
                ("p50", Json::Num(p50)),
                ("p90", Json::Num(p90)),
                ("p99", Json::Num(p99)),
                ("max", Json::Num(max)),
            ]),
            None => Json::Null,
        };
        let rejected = Json::Obj(
            self.rejected
                .iter()
                .map(|(c, n)| (c.clone(), Json::num(*n as f64)))
                .collect(),
        );
        let tenants = Json::Obj(
            self.tenants
                .iter()
                .map(|t| {
                    (
                        t.tenant.clone(),
                        Json::obj(vec![
                            ("weight", Json::num(t.weight as f64)),
                            ("done", Json::num(t.done as f64)),
                            ("steps", Json::num(t.steps as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("bench", Json::str("serve")),
            ("arrivals", Json::num(self.arrivals as f64)),
            ("accepted", Json::num(self.accepted as f64)),
            ("rejected", rejected),
            ("wall_secs", Json::Num(self.wall_secs)),
            ("jobs_done", Json::num(self.jobs_done as f64)),
            ("jobs_failed", Json::num(self.jobs_failed as f64)),
            ("jobs_per_sec", Json::Num(self.jobs_per_sec())),
            ("latency_s", latency),
            ("preempts", Json::num(self.preempts as f64)),
            ("resumes", Json::num(self.resumes as f64)),
            ("fleet_steps", Json::num(self.fleet_steps as f64)),
            ("squeezes_applied", Json::num(self.squeezes_applied as f64)),
            ("fairness_ratio", Json::Num(self.fairness_ratio())),
            ("tenants", tenants),
        ])
    }

    pub fn render(&self) -> String {
        let mut out = String::from("## loadgen report\n\n");
        out.push_str(&format!(
            "arrivals {} | accepted {} | rejected {} | wall {:.2}s | \
             {:.0} jobs/s\n",
            self.arrivals,
            self.accepted,
            self.rejected.iter().map(|(_, n)| n).sum::<usize>(),
            self.wall_secs,
            self.jobs_per_sec()
        ));
        out.push_str(&format!(
            "done {} | failed {} | preempts {} | resumes {} | fleet steps \
             {} | squeezes {}\n",
            self.jobs_done,
            self.jobs_failed,
            self.preempts,
            self.resumes,
            self.fleet_steps,
            self.squeezes_applied
        ));
        if let Some((count, mean, p50, p90, p99, max)) = self.latency_s {
            out.push_str(&format!(
                "latency (n={count}): mean {mean:.4}s p50 {p50:.4}s p90 \
                 {p90:.4}s p99 {p99:.4}s max {max:.4}s\n"
            ));
        }
        out.push_str(&format!(
            "fairness ratio {:.3} across {} tenants\n",
            self.fairness_ratio(),
            self.tenants.len()
        ));
        for t in &self.tenants {
            out.push_str(&format!(
                "  {}: weight {} done {} steps {}\n",
                t.tenant, t.weight, t.done, t.steps
            ));
        }
        out
    }
}

fn get_u64(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0) as u64
}

/// Replay the trace against a live daemon and collect the report.
pub fn run(opts: &LoadgenOptions) -> anyhow::Result<LoadgenReport> {
    anyhow::ensure!(opts.arrivals > 0, "loadgen needs at least one arrival");
    anyhow::ensure!(opts.tenants > 0, "loadgen needs at least one tenant");
    let trace = synth_trace(opts);
    let mut client = Client::connect(&opts.socket)?;

    let start = Instant::now();
    let mut accepted = 0usize;
    let mut rejected: Vec<(String, usize)> = Vec::new();
    let mut squeezes = opts.squeezes.iter().peekable();
    let mut squeezes_applied = 0usize;

    for (i, a) in trace.iter().enumerate() {
        // Pace against trace time when asked to.
        if opts.time_scale > 0.0 {
            let due = Duration::from_secs_f64(a.at_s / opts.time_scale);
            let elapsed = start.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
        }
        let mut spec = vec![("steps", Json::num(opts.steps as f64))];
        if a.priority > 0 {
            spec.push(("priority", Json::num(a.priority as f64)));
        }
        let mut fields = vec![
            ("spec", Json::obj(spec)),
            ("tenant", Json::str(&a.tenant)),
        ];
        if !opts.real {
            fields.push(("sim", Json::Bool(true)));
            if opts.sim_us > 0 {
                fields.push(("sim_us", Json::num(opts.sim_us as f64)));
            }
        }
        let r = client.call("submit", fields)?;
        if r.ok {
            accepted += 1;
        } else {
            let code = r
                .error
                .map(|(c, _)| c)
                .unwrap_or_else(|| "internal".to_string());
            match rejected.iter_mut().find(|(c, _)| *c == code) {
                Some((_, n)) => *n += 1,
                None => rejected.push((code, 1)),
            }
        }
        if let Some(&&(idx, bytes)) = squeezes.peek() {
            if i >= idx {
                squeezes.next();
                let r = client.call(
                    "set-budget",
                    vec![("budget_bytes", Json::num(bytes as f64))],
                )?;
                anyhow::ensure!(
                    r.ok,
                    "squeeze at arrival {idx} rejected: {:?}",
                    r.error
                );
                squeezes_applied += 1;
            }
        }
    }

    // Drain: poll status until nothing is queued, running or parked.
    let status = loop {
        let r = client.call("status", vec![])?;
        anyhow::ensure!(r.ok, "status rejected: {:?}", r.error);
        let jobs = r.data.get("jobs").cloned().unwrap_or(Json::Null);
        let active = get_u64(&jobs, "queued")
            + get_u64(&jobs, "running")
            + get_u64(&jobs, "parked");
        if active == 0 {
            break r.data;
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let wall_secs = start.elapsed().as_secs_f64();

    let jobs = status.get("jobs").cloned().unwrap_or(Json::Null);
    let latency_s = status.get("latency_s").and_then(|l| {
        l.as_obj().map(|_| {
            (
                get_u64(l, "count"),
                l.get("mean").and_then(|v| v.as_f64()).unwrap_or(0.0),
                l.get("p50").and_then(|v| v.as_f64()).unwrap_or(0.0),
                l.get("p90").and_then(|v| v.as_f64()).unwrap_or(0.0),
                l.get("p99").and_then(|v| v.as_f64()).unwrap_or(0.0),
                l.get("max").and_then(|v| v.as_f64()).unwrap_or(0.0),
            )
        })
    });
    let tenants = status
        .get("tenants")
        .and_then(|t| t.as_obj())
        .map(|obj| {
            obj.iter()
                .map(|(name, t)| TenantService {
                    tenant: name.clone(),
                    weight: get_u64(t, "weight"),
                    done: get_u64(t, "done"),
                    steps: get_u64(t, "steps"),
                })
                .collect()
        })
        .unwrap_or_default();

    if opts.shutdown {
        let r = client.call("shutdown", vec![])?;
        anyhow::ensure!(r.ok, "shutdown rejected: {:?}", r.error);
    }

    let report = LoadgenReport {
        arrivals: opts.arrivals,
        accepted,
        rejected,
        wall_secs,
        jobs_done: get_u64(&jobs, "done"),
        jobs_failed: get_u64(&jobs, "failed"),
        latency_s,
        preempts: get_u64(&status, "preempts"),
        resumes: get_u64(&status, "resumes"),
        fleet_steps: get_u64(&status, "fleet_steps"),
        squeezes_applied,
        tenants,
    };
    std::fs::write(&opts.out, report.to_json().to_string()).map_err(|e| {
        anyhow::anyhow!("write {}: {e}", opts.out.display())
    })?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_in_the_seed() {
        let opts = LoadgenOptions { arrivals: 500, ..Default::default() };
        let a = synth_trace(&opts);
        let b = synth_trace(&opts);
        assert_eq!(a, b, "same seed, same trace, bit for bit");
        let c = synth_trace(&LoadgenOptions { seed: 43, ..opts });
        assert_ne!(a, c, "different seed, different trace");
    }

    #[test]
    fn trace_arrivals_are_ordered_and_spread_over_tenants() {
        let opts = LoadgenOptions {
            arrivals: 2000,
            tenants: 4,
            ..Default::default()
        };
        let trace = synth_trace(&opts);
        assert_eq!(trace.len(), 2000);
        for w in trace.windows(2) {
            assert!(w[0].at_s <= w[1].at_s, "arrival times must ascend");
        }
        for t in 0..4 {
            let name = format!("t{t}");
            let n = trace.iter().filter(|a| a.tenant == name).count();
            assert!(
                n > 2000 / 4 / 2,
                "tenant {name} got only {n} of 2000 arrivals"
            );
        }
        let bumped = trace.iter().filter(|a| a.priority > 0).count();
        assert!(
            bumped > 100 && bumped < 400,
            "~10% of arrivals get a priority bump, got {bumped}"
        );
    }

    #[test]
    fn bursts_compress_inter_arrival_times() {
        let base = LoadgenOptions {
            arrivals: 1000,
            burst_every: 0,
            diurnal_amp: 0.0,
            ..Default::default()
        };
        let calm = synth_trace(&base);
        let bursty = synth_trace(&LoadgenOptions {
            burst_every: 100,
            burst_len: 100, // every arrival is in a burst
            burst_x: 10.0,
            ..base
        });
        // Identical seed: same uniforms, so an always-on 10x burst
        // divides the total span by ~10.
        let span = |t: &[Arrival]| t.last().unwrap().at_s;
        assert!(
            span(&bursty) < span(&calm) / 5.0,
            "bursts must compress the trace: calm {:.2}s bursty {:.2}s",
            span(&calm),
            span(&bursty)
        );
    }

    #[test]
    fn squeeze_list_parses_and_validates() {
        let s = parse_squeezes("100:48,500:24").unwrap();
        assert_eq!(s, vec![(100, 48 << 20), (500, 24 << 20)]);
        for bad in ["", "100", "100:", ":48", "x:48", "100:0", "500:24,100:48"]
        {
            assert!(parse_squeezes(bad).is_err(), "must reject '{bad}'");
        }
    }

    #[test]
    fn fairness_ratio_of_proportional_service_is_one() {
        let mk = |w: u64, steps: u64| TenantService {
            tenant: format!("t{w}"),
            weight: w,
            done: 1,
            steps,
        };
        let rep = LoadgenReport {
            arrivals: 0,
            accepted: 0,
            rejected: Vec::new(),
            wall_secs: 1.0,
            jobs_done: 0,
            jobs_failed: 0,
            latency_s: None,
            preempts: 0,
            resumes: 0,
            fleet_steps: 0,
            squeezes_applied: 0,
            tenants: vec![mk(1, 100), mk(2, 200), mk(4, 400)],
        };
        assert!((rep.fairness_ratio() - 1.0).abs() < 1e-9);
        let skewed = LoadgenReport {
            tenants: vec![mk(1, 100), mk(2, 600)],
            ..rep
        };
        assert!((skewed.fairness_ratio() - 3.0).abs() < 1e-9);
    }
}
