//! The `mesp serve` wire protocol: versioned JSONL frames over a Unix
//! socket.
//!
//! One request per line, one response per line, always in order. Every
//! request carries the protocol version (`"v"`), a client-chosen
//! correlation id (`"id"`, echoed back verbatim), and a `"verb"`; the
//! remaining keys are verb-specific and ALLOWLISTED — an unknown key is
//! a hard error, the same discipline as the CLI flag allowlists and the
//! job-file keys. Responses are `{"v":1,"id":N,"ok":true,"data":{...}}`
//! or `{"v":1,"id":N,"ok":false,"error":{"code":"...","message":"..."}}`.
//!
//! Parsing NEVER panics on any input (property-tested): truncated,
//! garbage, oversized and version-skewed frames all map to a named
//! [`code`] with a human message. The daemon replies to a malformed
//! frame (rather than dropping the connection) so a client can correlate
//! the failure — `id` is `null` in the reply only when the frame was too
//! broken to recover it.
//!
//! The full operator-facing specification (every verb, field and error
//! code, with worked examples) lives in `docs/serving.md` and must stay
//! in sync with this module.

use crate::util::json::Json;

/// Protocol version. Bump on ANY incompatible frame change; the daemon
/// rejects other versions with [`code::BAD_VERSION`] so an old client
/// fails loudly instead of misbehaving quietly.
pub const PROTOCOL_VERSION: u64 = 1;

/// Upper bound on one frame (request line) in bytes. A frame past this
/// is rejected with [`code::OVERSIZED_FRAME`] — a defense against a
/// stuck client streaming an unterminated line at the daemon.
pub const MAX_FRAME_BYTES: usize = 64 * 1024;

/// Named protocol error codes. Stable strings: clients switch on these,
/// tests assert on them, `docs/serving.md` documents each one.
pub mod code {
    /// The line is not valid JSON (or not a JSON object).
    pub const BAD_JSON: &str = "bad-json";
    /// `"v"` is missing or not [`super::PROTOCOL_VERSION`].
    pub const BAD_VERSION: &str = "bad-version";
    /// The request line exceeds [`super::MAX_FRAME_BYTES`].
    pub const OVERSIZED_FRAME: &str = "oversized-frame";
    /// A required field is absent.
    pub const MISSING_FIELD: &str = "missing-field";
    /// A field is present but has the wrong type/value, or is not in
    /// the verb's allowlist.
    pub const BAD_FIELD: &str = "bad-field";
    /// `"verb"` names no known verb.
    pub const UNKNOWN_VERB: &str = "unknown-verb";
    /// `cancel`/`status` named a job id the daemon has never seen.
    pub const UNKNOWN_JOB: &str = "unknown-job";
    /// `submit`'s `"spec"` failed job-spec validation (unknown key, bad
    /// value, unknown config, ...).
    pub const BAD_SPEC: &str = "bad-spec";
    /// The spec is valid but its solo footprint can never fit the
    /// budget ceiling — admitting it would only ever fail.
    pub const OVER_BUDGET: &str = "over-budget";
    /// The spec's cost alone exceeds the submitting tenant's quota, so
    /// the job could never be admitted for that tenant.
    pub const QUOTA_EXCEEDED: &str = "quota-exceeded";
    /// The daemon is draining (or shutting down) and accepts no new work.
    pub const DRAINING: &str = "draining";
    /// The daemon hit an unexpected internal error serving the request.
    pub const INTERNAL: &str = "internal";
}

/// A protocol-level failure: a stable machine code plus a human message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProtoError {
    pub code: &'static str,
    pub message: String,
}

impl ProtoError {
    pub fn new(code: &'static str, message: impl Into<String>) -> ProtoError {
        ProtoError { code, message: message.into() }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

/// The tenant a submit without an explicit `"tenant"` lands in.
pub const DEFAULT_TENANT: &str = "default";

/// One parsed request verb with its validated fields.
#[derive(Debug, Clone, PartialEq)]
pub enum Verb {
    /// Submit one job. `spec` is the raw job object (validated against
    /// the daemon's base config by `fleet::job::JobSpec::from_json` at
    /// dispatch — the protocol layer only checks it IS an object).
    Submit { spec: Json, tenant: String, sim: bool, sim_us: u64 },
    /// Aggregate daemon status (`job: None`) or one job's status.
    Status { job: Option<u64> },
    /// Cooperatively cancel a job (queued: immediate; running: at the
    /// next step boundary; parked: immediate, snapshot deleted).
    Cancel { job: u64 },
    /// Change the admission budget mid-run (the loadgen's squeeze lever).
    /// `ceiling_bytes: None` keeps the refusal ceiling where it was, so
    /// a squeeze parks jobs instead of permanently refusing them.
    SetBudget { budget_bytes: u64, ceiling_bytes: Option<u64> },
    /// Stop accepting submits; the daemon exits once all work is done.
    Drain,
    /// Stop now: running jobs park to snapshots, the daemon exits.
    Shutdown,
}

/// A parsed, validated request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Client correlation id, echoed in the response.
    pub id: u64,
    pub verb: Verb,
}

fn missing(key: &str) -> ProtoError {
    ProtoError::new(code::MISSING_FIELD, format!("missing field '{key}'"))
}

fn bad_field(key: &str, why: impl std::fmt::Display) -> ProtoError {
    ProtoError::new(code::BAD_FIELD, format!("field '{key}': {why}"))
}

/// A field that must be a non-negative integer within f64's exact range
/// (ids, byte counts): fractional, negative and huge values are errors,
/// never silent truncations.
fn as_u64(v: &Json, key: &str) -> Result<u64, ProtoError> {
    let n = v
        .as_f64()
        .ok_or_else(|| bad_field(key, "must be a number"))?;
    if !(n >= 0.0 && n.fract() == 0.0 && n <= (1u64 << 53) as f64) {
        return Err(bad_field(
            key,
            format!("must be a non-negative integer <= 2^53, got {n}"),
        ));
    }
    Ok(n as u64)
}

fn as_bool(v: &Json, key: &str) -> Result<bool, ProtoError> {
    match v {
        Json::Bool(b) => Ok(*b),
        _ => Err(bad_field(key, "must be a boolean")),
    }
}

/// Keys every request frame carries.
const COMMON_KEYS: &[&str] = &["v", "id", "verb"];

/// Per-verb extra-key allowlists (mirrors the CLI's per-subcommand flag
/// allowlists; asserted against the parser by a test below).
pub const SUBMIT_KEYS: &[&str] = &["spec", "tenant", "sim", "sim_us"];
pub const STATUS_KEYS: &[&str] = &["job"];
pub const CANCEL_KEYS: &[&str] = &["job"];
pub const SET_BUDGET_KEYS: &[&str] = &["budget_bytes", "ceiling_bytes"];

/// Every verb the protocol knows, in documentation order.
pub const VERBS: &[&str] =
    &["submit", "status", "cancel", "set-budget", "drain", "shutdown"];

/// Parse and validate one request line. Returns a named [`ProtoError`]
/// for every malformed input; never panics.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    if line.len() > MAX_FRAME_BYTES {
        return Err(ProtoError::new(
            code::OVERSIZED_FRAME,
            format!(
                "frame is {} bytes, limit {MAX_FRAME_BYTES}",
                line.len()
            ),
        ));
    }
    let j = Json::parse(line.trim())
        .map_err(|e| ProtoError::new(code::BAD_JSON, format!("{e}")))?;
    let obj = j.as_obj().ok_or_else(|| {
        ProtoError::new(code::BAD_JSON, "frame must be a JSON object")
    })?;
    let v = as_u64(obj.get("v").ok_or_else(|| missing("v"))?, "v")?;
    if v != PROTOCOL_VERSION {
        return Err(ProtoError::new(
            code::BAD_VERSION,
            format!("protocol version {v}, daemon speaks {PROTOCOL_VERSION}"),
        ));
    }
    let id = as_u64(obj.get("id").ok_or_else(|| missing("id"))?, "id")?;
    let verb_name = obj
        .get("verb")
        .ok_or_else(|| missing("verb"))?
        .as_str()
        .ok_or_else(|| bad_field("verb", "must be a string"))?;
    let extra: &[&str] = match verb_name {
        "submit" => SUBMIT_KEYS,
        "status" => STATUS_KEYS,
        "cancel" => CANCEL_KEYS,
        "set-budget" => SET_BUDGET_KEYS,
        "drain" | "shutdown" => &[],
        other => {
            return Err(ProtoError::new(
                code::UNKNOWN_VERB,
                format!("unknown verb '{other}' (known: {})", VERBS.join(", ")),
            ))
        }
    };
    for k in obj.keys() {
        if !COMMON_KEYS.contains(&k.as_str()) && !extra.contains(&k.as_str()) {
            return Err(bad_field(
                k,
                format!(
                    "not a '{verb_name}' field (known: {})",
                    extra.join(", ")
                ),
            ));
        }
    }
    let verb = match verb_name {
        "submit" => {
            let spec = obj.get("spec").ok_or_else(|| missing("spec"))?;
            if spec.as_obj().is_none() {
                return Err(bad_field("spec", "must be a JSON object"));
            }
            let tenant = match obj.get("tenant") {
                None => DEFAULT_TENANT.to_string(),
                Some(t) => {
                    let t = t
                        .as_str()
                        .ok_or_else(|| bad_field("tenant", "must be a string"))?;
                    if t.is_empty() {
                        return Err(bad_field("tenant", "must be non-empty"));
                    }
                    t.to_string()
                }
            };
            let sim = match obj.get("sim") {
                None => false,
                Some(b) => as_bool(b, "sim")?,
            };
            let sim_us = match obj.get("sim_us") {
                None => 0,
                Some(n) => as_u64(n, "sim_us")?,
            };
            Verb::Submit { spec: spec.clone(), tenant, sim, sim_us }
        }
        "status" => Verb::Status {
            job: obj.get("job").map(|v| as_u64(v, "job")).transpose()?,
        },
        "cancel" => Verb::Cancel {
            job: as_u64(obj.get("job").ok_or_else(|| missing("job"))?, "job")?,
        },
        "set-budget" => Verb::SetBudget {
            budget_bytes: as_u64(
                obj.get("budget_bytes")
                    .ok_or_else(|| missing("budget_bytes"))?,
                "budget_bytes",
            )?,
            ceiling_bytes: obj
                .get("ceiling_bytes")
                .map(|v| as_u64(v, "ceiling_bytes"))
                .transpose()?,
        },
        "drain" => Verb::Drain,
        "shutdown" => Verb::Shutdown,
        _ => unreachable!("verb allowlist matched above"),
    };
    Ok(Request { id, verb })
}

/// Serialize a success response frame.
pub fn ok_frame(id: u64, data: Json) -> String {
    Json::obj(vec![
        ("v", Json::num(PROTOCOL_VERSION as f64)),
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("data", data),
    ])
    .to_string()
}

/// Serialize an error response frame. `id: None` (the frame was too
/// malformed to recover one) serializes as `"id": null`.
pub fn err_frame(id: Option<u64>, e: &ProtoError) -> String {
    Json::obj(vec![
        ("v", Json::num(PROTOCOL_VERSION as f64)),
        ("id", id.map_or(Json::Null, |i| Json::num(i as f64))),
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::str(e.code)),
                ("message", Json::str(&e.message)),
            ]),
        ),
    ])
    .to_string()
}

/// A response frame as the CLIENT sees it (loadgen, tests).
#[derive(Debug, Clone)]
pub struct Response {
    pub id: Option<u64>,
    pub ok: bool,
    /// Present iff `ok`.
    pub data: Json,
    /// `(code, message)`, present iff `!ok`.
    pub error: Option<(String, String)>,
}

/// Parse a response line on the client side.
pub fn parse_response(line: &str) -> anyhow::Result<Response> {
    let j = Json::parse(line.trim())
        .map_err(|e| anyhow::anyhow!("bad response frame: {e}"))?;
    let v = j
        .get("v")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow::anyhow!("response missing 'v'"))?;
    anyhow::ensure!(
        v == PROTOCOL_VERSION as f64,
        "response protocol version {v}, client speaks {PROTOCOL_VERSION}"
    );
    let id = match j.get("id") {
        Some(Json::Null) | None => None,
        Some(n) => Some(n.as_f64().ok_or_else(|| {
            anyhow::anyhow!("response 'id' must be a number or null")
        })? as u64),
    };
    let ok = match j.get("ok") {
        Some(Json::Bool(b)) => *b,
        _ => anyhow::bail!("response missing boolean 'ok'"),
    };
    let error = if ok {
        None
    } else {
        let e = j
            .get("error")
            .ok_or_else(|| anyhow::anyhow!("error response missing 'error'"))?;
        Some((
            e.get("code")
                .and_then(|c| c.as_str())
                .unwrap_or("internal")
                .to_string(),
            e.get("message")
                .and_then(|m| m.as_str())
                .unwrap_or("")
                .to_string(),
        ))
    };
    Ok(Response {
        id,
        ok,
        data: j.get("data").cloned().unwrap_or(Json::Null),
        error,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(s: &str) -> Result<Request, ProtoError> {
        parse_request(s)
    }

    #[test]
    fn submit_roundtrip_with_defaults() {
        let r = req(r#"{"v":1,"id":7,"verb":"submit","spec":{"steps":3}}"#)
            .unwrap();
        assert_eq!(r.id, 7);
        match r.verb {
            Verb::Submit { spec, tenant, sim, sim_us } => {
                assert_eq!(spec.get("steps").unwrap().as_usize(), Some(3));
                assert_eq!(tenant, DEFAULT_TENANT);
                assert!(!sim);
                assert_eq!(sim_us, 0);
            }
            v => panic!("wrong verb: {v:?}"),
        }
    }

    #[test]
    fn submit_with_tenant_and_sim() {
        let r = req(
            r#"{"v":1,"id":1,"verb":"submit","spec":{},"tenant":"alice","sim":true,"sim_us":50}"#,
        )
        .unwrap();
        match r.verb {
            Verb::Submit { tenant, sim, sim_us, .. } => {
                assert_eq!(tenant, "alice");
                assert!(sim);
                assert_eq!(sim_us, 50);
            }
            v => panic!("wrong verb: {v:?}"),
        }
    }

    #[test]
    fn every_verb_parses() {
        // VERBS is the advertised list; each must parse with minimal
        // valid fields (the docs' spec table mirrors this).
        for (verb, extra) in [
            ("submit", r#","spec":{}"#),
            ("status", ""),
            ("cancel", r#","job":0"#),
            ("set-budget", r#","budget_bytes":1048576"#),
            ("drain", ""),
            ("shutdown", ""),
        ] {
            assert!(VERBS.contains(&verb), "test table missing {verb}");
            let line = format!(r#"{{"v":1,"id":0,"verb":"{verb}"{extra}}}"#);
            assert!(req(&line).is_ok(), "advertised verb '{verb}' rejected");
        }
        assert_eq!(VERBS.len(), 6, "update the table when adding verbs");
    }

    #[test]
    fn garbage_maps_to_bad_json() {
        for bad in ["", "not json", "{", "[1,2]", "42", "\"str\"", "{}x"] {
            let e = req(bad).unwrap_err();
            assert_eq!(e.code, code::BAD_JSON, "{bad}: {e}");
        }
    }

    #[test]
    fn version_skew_rejected() {
        let e = req(r#"{"v":2,"id":0,"verb":"drain"}"#).unwrap_err();
        assert_eq!(e.code, code::BAD_VERSION);
        let e = req(r#"{"id":0,"verb":"drain"}"#).unwrap_err();
        assert_eq!(e.code, code::MISSING_FIELD);
        let e = req(r#"{"v":"one","id":0,"verb":"drain"}"#).unwrap_err();
        assert_eq!(e.code, code::BAD_FIELD);
    }

    #[test]
    fn missing_and_bad_fields_are_named() {
        let e = req(r#"{"v":1,"verb":"drain"}"#).unwrap_err();
        assert_eq!(e.code, code::MISSING_FIELD);
        assert!(e.message.contains("'id'"), "{e}");
        let e = req(r#"{"v":1,"id":0,"verb":"cancel"}"#).unwrap_err();
        assert_eq!(e.code, code::MISSING_FIELD);
        assert!(e.message.contains("'job'"), "{e}");
        let e = req(r#"{"v":1,"id":0,"verb":"submit"}"#).unwrap_err();
        assert_eq!(e.code, code::MISSING_FIELD);
        let e =
            req(r#"{"v":1,"id":0,"verb":"submit","spec":[]}"#).unwrap_err();
        assert_eq!(e.code, code::BAD_FIELD);
        let e = req(r#"{"v":1,"id":0,"verb":"cancel","job":-1}"#).unwrap_err();
        assert_eq!(e.code, code::BAD_FIELD);
        let e =
            req(r#"{"v":1,"id":0,"verb":"cancel","job":1.5}"#).unwrap_err();
        assert_eq!(e.code, code::BAD_FIELD);
    }

    #[test]
    fn unknown_verb_and_unknown_key_rejected() {
        let e = req(r#"{"v":1,"id":0,"verb":"frobnicate"}"#).unwrap_err();
        assert_eq!(e.code, code::UNKNOWN_VERB);
        assert!(e.message.contains("submit"), "lists known verbs: {e}");
        let e = req(r#"{"v":1,"id":0,"verb":"drain","spec":{}}"#).unwrap_err();
        assert_eq!(e.code, code::BAD_FIELD);
        let e = req(r#"{"v":1,"id":0,"verb":"status","jov":3}"#).unwrap_err();
        assert_eq!(e.code, code::BAD_FIELD);
    }

    #[test]
    fn oversized_frame_rejected() {
        let line = format!(
            r#"{{"v":1,"id":0,"verb":"submit","spec":{{"config":"{}"}}}}"#,
            "x".repeat(MAX_FRAME_BYTES)
        );
        let e = req(&line).unwrap_err();
        assert_eq!(e.code, code::OVERSIZED_FRAME);
    }

    #[test]
    fn response_frames_roundtrip() {
        let f = ok_frame(9, Json::obj(vec![("job", Json::num(3.0))]));
        let r = parse_response(&f).unwrap();
        assert_eq!(r.id, Some(9));
        assert!(r.ok);
        assert_eq!(r.data.get("job").unwrap().as_usize(), Some(3));

        let f = err_frame(
            None,
            &ProtoError::new(code::BAD_JSON, "line 1 is not JSON"),
        );
        let r = parse_response(&f).unwrap();
        assert_eq!(r.id, None);
        assert!(!r.ok);
        let (c, m) = r.error.unwrap();
        assert_eq!(c, code::BAD_JSON);
        assert!(m.contains("not JSON"));
    }

    #[test]
    fn truncated_frames_error_not_panic() {
        let full = r#"{"v":1,"id":7,"verb":"submit","spec":{"steps":3},"tenant":"aé"}"#;
        for (n, _) in full.char_indices() {
            let cut = &full[..n];
            if let Err(e) = req(cut) {
                assert!(!e.code.is_empty());
            }
        }
    }
}
