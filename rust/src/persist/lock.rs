//! Daemon liveness lock: one `mesp serve` per snapshot directory.
//!
//! Crash recovery re-admits every parked session found in
//! `--snapshot-dir`, so two daemons scanning the same directory would
//! resume the same jobs twice. The lock is a plain pid file (the
//! offline build has no `flock` crate): acquisition reads any existing
//! file and refuses only if the recorded pid is still alive (its
//! `/proc/<pid>` entry exists). A stale file — the previous daemon was
//! SIGKILLed — is silently replaced; that is exactly the crash-recovery
//! path. On clean shutdown the lock removes itself (RAII drop).
//!
//! Liveness via `/proc` is Linux-pragmatic: on a system without procfs
//! every lock looks stale. The failure mode is the benign direction for
//! a development machine (a forgotten lock never wedges recovery), and
//! the deployment target of the paper is Linux-kernel devices.

use std::path::{Path, PathBuf};

/// RAII pid-file lock on a directory. See the module docs.
#[derive(Debug)]
pub struct LockFile {
    path: PathBuf,
}

/// Whether `pid` names a live process (procfs probe).
fn pid_alive(pid: u32) -> bool {
    pid != 0 && Path::new(&format!("/proc/{pid}")).exists()
}

impl LockFile {
    /// Acquire `dir/name`, creating `dir` if needed. Fails if another
    /// LIVE process holds the lock; replaces a stale (dead-pid) file.
    pub fn acquire(dir: &Path, name: &str) -> anyhow::Result<LockFile> {
        std::fs::create_dir_all(dir).map_err(|e| {
            anyhow::anyhow!("create lock dir {}: {e}", dir.display())
        })?;
        let path = dir.join(name);
        if let Ok(existing) = std::fs::read_to_string(&path) {
            let pid: u32 = existing.trim().parse().unwrap_or(0);
            if pid_alive(pid) {
                anyhow::bail!(
                    "lock file {} is held by live pid {pid} — another \
                     daemon is serving this snapshot dir",
                    path.display()
                );
            }
        }
        std::fs::write(&path, format!("{}\n", std::process::id())).map_err(
            |e| anyhow::anyhow!("write lock file {}: {e}", path.display()),
        )?;
        Ok(LockFile { path })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for LockFile {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mesp-test-lock-{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn acquire_write_release_cycle() {
        let d = dir("cycle");
        let lock = LockFile::acquire(&d, "serve.lock").unwrap();
        let on_disk = std::fs::read_to_string(lock.path()).unwrap();
        assert_eq!(
            on_disk.trim().parse::<u32>().unwrap(),
            std::process::id()
        );
        let path = lock.path().to_path_buf();
        drop(lock);
        assert!(!path.exists(), "clean release removes the file");
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn live_holder_blocks_second_acquire() {
        let d = dir("live");
        // Our own pid is as live as it gets.
        let lock = LockFile::acquire(&d, "serve.lock").unwrap();
        let err = LockFile::acquire(&d, "serve.lock").unwrap_err().to_string();
        assert!(err.contains("held by live pid"), "{err}");
        drop(lock);
        let _ = std::fs::remove_dir_all(&d);
    }

    #[test]
    fn stale_lock_is_replaced() {
        let d = dir("stale");
        std::fs::create_dir_all(&d).unwrap();
        // A pid far beyond any default pid_max: certainly not alive.
        std::fs::write(d.join("serve.lock"), "4999999999\n").unwrap();
        let lock = LockFile::acquire(&d, "serve.lock").unwrap();
        drop(lock);
        // Garbage content is treated as stale too (SIGKILL can truncate).
        std::fs::write(d.join("serve.lock"), "not a pid\n").unwrap();
        let lock = LockFile::acquire(&d, "serve.lock").unwrap();
        drop(lock);
        let _ = std::fs::remove_dir_all(&d);
    }
}
