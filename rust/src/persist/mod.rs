//! Session persistence: suspend a live fine-tuning session to a single
//! binary snapshot file and resume it later — bitwise-identically.
//!
//! The paper's deployment target shares 6–12 GB with every other
//! workload on the device, so a training job must expect to be parked by
//! the OS (or by our own fleet scheduler when the budget shrinks) and
//! picked back up without losing work — MeBP-style systems assume
//! interruption as the common case, not the exception. This module is
//! the mechanism: [`Snapshot`] captures exactly the state that cannot be
//! regenerated from the config — LoRA adapters, optimizer moments, the
//! step counter, the data-loader cursor and the derived RNG stream
//! seeds — and fingerprints everything that can (the frozen base
//! weights, which restore regenerates from the model stream seed and
//! verifies by checksum; under q4 the fingerprint covers the int4-packed
//! bytes, so packed residents stay packed on disk).
//!
//! The contract, enforced by `tests/persist.rs` and the CI resume tier:
//! a run suspended at step k and resumed reproduces the uninterrupted
//! run **bitwise** — same losses, same adapters — for every method,
//! quant mode, kernel variant and thread count.
//!
//! See [`snapshot`] for the on-disk layout and versioning policy, and
//! [`crate::coordinator::TrainSession::snapshot`] /
//! [`crate::coordinator::SessionBuilder::resume_from`] for the
//! session-level entry points the CLI (`train --save-every/--resume`)
//! and the fleet scheduler's preempt-to-disk path are built on. A
//! resumed session re-attaches to its frozen base by fingerprint — if a
//! cached [`crate::model::WeightCache`] entry for the same base is live,
//! restore shares it instead of regenerating.

//!
//! The `mesp serve` daemon builds its crash-recovery contract on the
//! same files: a per-job JSON sidecar plus the newest step snapshot in
//! `--snapshot-dir` fully describe an interrupted job, and [`lock`]
//! guarantees only one daemon at a time rescans (and re-admits) them.

pub mod codec;
pub mod lock;
pub mod snapshot;

pub use codec::{fnv1a64, fnv1a64_tensor, Reader, Writer};
pub use lock::LockFile;
pub use snapshot::{RngStreams, Snapshot, HEADER_LEN, MAGIC, VERSION};
