//! The versioned, checksummed session-snapshot format.
//!
//! # On-disk layout (version 1)
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"MESPSNAP"
//! 8       4     format version (u32 LE) — currently 1
//! 12      8     payload length in bytes (u64 LE)
//! 20      8     FNV-1a 64 checksum of the payload (u64 LE)
//! 28      len   payload (see below)
//! ```
//!
//! Payload, in order (all integers LE, f32 as raw IEEE-754 bits):
//!
//! 1. identity — config name (string), method, quant mode, optimizer
//!    kind + hyperparameters, learning rate, base seed;
//! 2. progress — optimizer step counter, data-loader cursor (batches
//!    consumed since session start);
//! 3. RNG stream states — the three `util::rng::derive` sub-seeds
//!    (model / loader / job) the session was built from, re-derived and
//!    cross-checked on restore;
//! 4. base-weight fingerprint — FNV-1a 64 over every resident frozen
//!    tensor in upload (artifact-ABI) order. Frozen weights are pure
//!    functions of the model stream seed, so the snapshot does NOT store
//!    them: restore regenerates and verifies them against this hash.
//!    Under q4 the fingerprint covers the int4-packed bytes + scales —
//!    packed residents stay packed on disk, never round-tripped through
//!    f32;
//! 5. LoRA adapters — every A/B tensor, layer-major, artifact-ABI order;
//! 6. optimizer moments — Adam `t`, then first/second-moment groups
//!    (empty for SGD, first-moment only for momentum).
//!
//! # Versioning policy
//!
//! The version is bumped whenever the payload layout changes; readers
//! accept exactly their own version and reject everything else with an
//! actionable error (no silent migration — a paused fine-tuning job is
//! worth less than a silently-wrong one). Corruption is detected by the
//! payload checksum before any field is interpreted.

use std::path::Path;

use crate::config::{Method, OptimizerKind, QuantMode};
use crate::tensor::HostTensor;
use crate::util::rng::{derive, stream};

use super::codec::{fnv1a64, Reader, Writer};

/// File magic — never changes across versions.
pub const MAGIC: &[u8; 8] = b"MESPSNAP";
/// Current (and only readable) format version.
pub const VERSION: u32 = 1;
/// Fixed header size: magic + version + payload length + checksum.
pub const HEADER_LEN: usize = 8 + 4 + 8 + 8;

/// The three derived sub-seeds a session draws from (see
/// [`crate::util::rng::derive`]). Pure functions of the base seed; stored
/// anyway so restore can prove the derivation scheme has not drifted
/// between the build that suspended and the build that resumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RngStreams {
    pub model: u64,
    pub loader: u64,
    pub job: u64,
}

impl RngStreams {
    pub fn derive_from(seed: u64) -> RngStreams {
        RngStreams {
            model: derive(seed, stream::MODEL),
            loader: derive(seed, stream::LOADER),
            job: derive(seed, stream::JOB),
        }
    }
}

/// A complete suspended training session — everything that cannot be
/// regenerated from the config: adapters, optimizer moments, counters —
/// plus enough identity and fingerprint data to refuse a mismatched
/// resume loudly.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub config: String,
    pub method: Method,
    pub quant: QuantMode,
    pub optimizer: OptimizerKind,
    pub lr: f32,
    pub seed: u64,
    /// Optimization steps completed when the session was suspended.
    pub step: u64,
    /// Batches drawn from the data loader (the loader cursor: restore
    /// fast-forwards the deterministic stream by this many batches).
    pub batches_consumed: u64,
    pub rng: RngStreams,
    /// FNV-1a 64 over the resident frozen weights (see module docs).
    pub weights_fingerprint: u64,
    /// LoRA adapters per layer, artifact-ABI order.
    pub lora: Vec<Vec<HostTensor>>,
    /// Adam bias-correction step counter (0 for SGD/momentum).
    pub opt_t: u64,
    /// First-moment groups (momentum `v` / Adam `m`; empty for SGD).
    pub opt_m1: Vec<Vec<f32>>,
    /// Second-moment groups (Adam `v`; empty otherwise).
    pub opt_m2: Vec<Vec<f32>>,
}

fn optimizer_tag(o: OptimizerKind) -> (u8, [f32; 3]) {
    match o {
        OptimizerKind::Sgd => (0, [0.0; 3]),
        OptimizerKind::Momentum { beta } => (1, [beta, 0.0, 0.0]),
        OptimizerKind::Adam { beta1, beta2, eps } => (2, [beta1, beta2, eps]),
    }
}

fn optimizer_from_tag(tag: u8, p: [f32; 3]) -> anyhow::Result<OptimizerKind> {
    Ok(match tag {
        0 => OptimizerKind::Sgd,
        1 => OptimizerKind::Momentum { beta: p[0] },
        2 => OptimizerKind::Adam { beta1: p[0], beta2: p[1], eps: p[2] },
        _ => anyhow::bail!("snapshot: unknown optimizer tag {tag}"),
    })
}

impl Snapshot {
    /// Serialize to the full file image (header + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.str(&self.config);
        w.str(self.method.name());
        w.str(self.quant.name());
        let (tag, params) = optimizer_tag(self.optimizer);
        w.u8(tag);
        for p in params {
            w.f32(p);
        }
        w.f32(self.lr);
        w.u64(self.seed);
        w.u64(self.step);
        w.u64(self.batches_consumed);
        w.u64(self.rng.model);
        w.u64(self.rng.loader);
        w.u64(self.rng.job);
        w.u64(self.weights_fingerprint);
        w.u32(self.lora.len() as u32);
        for layer in &self.lora {
            w.u32(layer.len() as u32);
            for t in layer {
                w.tensor(t);
            }
        }
        w.u64(self.opt_t);
        w.u32(self.opt_m1.len() as u32);
        for g in &self.opt_m1 {
            w.f32_slice(g);
        }
        w.u32(self.opt_m2.len() as u32);
        for g in &self.opt_m2 {
            w.f32_slice(g);
        }
        let payload = w.into_bytes();

        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Parse a full file image, validating magic, version, length and
    /// checksum before interpreting a single payload field.
    pub fn decode(bytes: &[u8]) -> anyhow::Result<Snapshot> {
        anyhow::ensure!(
            bytes.len() >= HEADER_LEN,
            "snapshot file truncated: {} bytes is smaller than the \
             {HEADER_LEN}-byte header",
            bytes.len()
        );
        anyhow::ensure!(
            &bytes[..8] == MAGIC,
            "not a mesp snapshot (bad magic {:02x?})",
            &bytes[..8]
        );
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        anyhow::ensure!(
            version == VERSION,
            "unsupported snapshot version {version} (this build reads \
             version {VERSION} only — re-snapshot with the matching build)"
        );
        let payload_len =
            u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        let checksum = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
        anyhow::ensure!(
            bytes.len() - HEADER_LEN == payload_len,
            "snapshot file truncated: header promises {payload_len} payload \
             bytes, file holds {}",
            bytes.len() - HEADER_LEN
        );
        let payload = &bytes[HEADER_LEN..];
        let actual = fnv1a64(payload);
        anyhow::ensure!(
            actual == checksum,
            "snapshot checksum mismatch (stored {checksum:#018x}, computed \
             {actual:#018x}) — the file is corrupted"
        );

        let mut r = Reader::new(payload);
        let config = r.str()?;
        let method = Method::parse(&r.str()?)?;
        let quant = QuantMode::parse(&r.str()?)?;
        let tag = r.u8()?;
        let params = [r.f32()?, r.f32()?, r.f32()?];
        let optimizer = optimizer_from_tag(tag, params)?;
        let lr = r.f32()?;
        let seed = r.u64()?;
        let step = r.u64()?;
        let batches_consumed = r.u64()?;
        let rng = RngStreams {
            model: r.u64()?,
            loader: r.u64()?,
            job: r.u64()?,
        };
        let weights_fingerprint = r.u64()?;
        let n_layers = r.u32()? as usize;
        anyhow::ensure!(
            n_layers <= 4096,
            "snapshot: implausible layer count {n_layers}"
        );
        let mut lora = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let n = r.u32()? as usize;
            anyhow::ensure!(n <= 1024, "snapshot: implausible tensor count {n}");
            let mut layer = Vec::with_capacity(n);
            for _ in 0..n {
                layer.push(r.tensor()?);
            }
            lora.push(layer);
        }
        let opt_t = r.u64()?;
        let n1 = r.u32()? as usize;
        let mut opt_m1 = Vec::with_capacity(n1.min(65_536));
        for _ in 0..n1 {
            opt_m1.push(r.f32_slice()?);
        }
        let n2 = r.u32()? as usize;
        let mut opt_m2 = Vec::with_capacity(n2.min(65_536));
        for _ in 0..n2 {
            opt_m2.push(r.f32_slice()?);
        }
        anyhow::ensure!(
            r.remaining() == 0,
            "snapshot: {} trailing bytes after the payload — file and \
             format version disagree",
            r.remaining()
        );
        Ok(Snapshot {
            config,
            method,
            quant,
            optimizer,
            lr,
            seed,
            step,
            batches_consumed,
            rng,
            weights_fingerprint,
            lora,
            opt_t,
            opt_m1,
            opt_m2,
        })
    }

    /// Write atomically (temp file + rename, so a crash mid-write never
    /// leaves a half-snapshot under the final name). Returns bytes
    /// written.
    pub fn save(&self, path: &Path) -> anyhow::Result<u64> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let bytes = self.encode();
        let tmp = path.with_extension("snap.tmp");
        std::fs::write(&tmp, &bytes)
            .map_err(|e| anyhow::anyhow!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("rename to {}: {e}", path.display()))?;
        Ok(bytes.len() as u64)
    }

    pub fn load(path: &Path) -> anyhow::Result<Snapshot> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("read snapshot {}: {e}", path.display()))?;
        Self::decode(&bytes)
            .map_err(|e| anyhow::anyhow!("snapshot {}: {e}", path.display()))
    }

    /// The training config a resumed session runs under: the snapshot's
    /// semantic identity (config/method/quant/optimizer/lr/seed) over the
    /// caller's wiring (backend, kernel, threads, step target, logging) —
    /// resume parity is bitwise on every kernel variant and thread count,
    /// so the execution knobs are free to differ across suspend/resume.
    pub fn train_config(
        &self,
        base: &crate::config::TrainConfig,
    ) -> crate::config::TrainConfig {
        crate::config::TrainConfig {
            config: self.config.clone(),
            method: self.method,
            quant: self.quant,
            optimizer: self.optimizer,
            lr: self.lr,
            seed: self.seed,
            ..base.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Snapshot {
        Snapshot {
            config: "toy".into(),
            method: Method::StoreH,
            quant: QuantMode::Q4,
            optimizer: OptimizerKind::Adam {
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
            },
            lr: 3e-4,
            seed: 42,
            step: 17,
            batches_consumed: 17,
            rng: RngStreams::derive_from(42),
            weights_fingerprint: 0xfeed_f00d,
            lora: vec![
                vec![
                    HostTensor::f32(&[2, 3], vec![0.5, -1.0, f32::NAN, 0.0, 2.0, -0.0]),
                    HostTensor::u8(&[2, 2], vec![1, 2, 3, 255]),
                ],
                vec![HostTensor::f32(&[1], vec![9.0])],
            ],
            opt_t: 17,
            opt_m1: vec![vec![0.1, 0.2], vec![]],
            opt_m2: vec![vec![-0.5], vec![1e-30]],
        }
    }

    fn assert_bitwise_eq(a: &Snapshot, b: &Snapshot) {
        assert_eq!(a.config, b.config);
        assert_eq!(a.method, b.method);
        assert_eq!(a.quant, b.quant);
        assert_eq!(a.optimizer, b.optimizer);
        assert_eq!(a.lr.to_bits(), b.lr.to_bits());
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.step, b.step);
        assert_eq!(a.batches_consumed, b.batches_consumed);
        assert_eq!(a.rng, b.rng);
        assert_eq!(a.weights_fingerprint, b.weights_fingerprint);
        assert_eq!(a.lora.len(), b.lora.len());
        for (la, lb) in a.lora.iter().zip(&b.lora) {
            assert_eq!(la.len(), lb.len());
            for (ta, tb) in la.iter().zip(lb) {
                assert_eq!(ta.shape, tb.shape);
                assert_eq!(ta.dtype(), tb.dtype());
                match (&ta.data, &tb.data) {
                    (crate::tensor::Data::F32(x), crate::tensor::Data::F32(y)) => {
                        assert!(x
                            .iter()
                            .zip(y)
                            .all(|(p, q)| p.to_bits() == q.to_bits()));
                    }
                    (crate::tensor::Data::U8(x), crate::tensor::Data::U8(y)) => {
                        assert_eq!(x, y)
                    }
                    (crate::tensor::Data::I32(x), crate::tensor::Data::I32(y)) => {
                        assert_eq!(x, y)
                    }
                    _ => panic!("dtype mismatch"),
                }
            }
        }
        assert_eq!(a.opt_t, b.opt_t);
        for (ga, gb) in a.opt_m1.iter().zip(&b.opt_m1) {
            assert!(ga.iter().zip(gb).all(|(p, q)| p.to_bits() == q.to_bits()));
        }
        for (ga, gb) in a.opt_m2.iter().zip(&b.opt_m2) {
            assert!(ga.iter().zip(gb).all(|(p, q)| p.to_bits() == q.to_bits()));
        }
    }

    #[test]
    fn encode_decode_identity() {
        let s = sample();
        let back = Snapshot::decode(&s.encode()).unwrap();
        assert_bitwise_eq(&s, &back);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        let err = Snapshot::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn wrong_version_rejected() {
        let mut bytes = sample().encode();
        bytes[8..12].copy_from_slice(&2u32.to_le_bytes());
        let err = Snapshot::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("unsupported snapshot version 2"), "{err}");
    }

    #[test]
    fn truncation_rejected() {
        let bytes = sample().encode();
        for cut in [0, 7, HEADER_LEN - 1, HEADER_LEN + 3, bytes.len() - 1] {
            let err = Snapshot::decode(&bytes[..cut]).unwrap_err().to_string();
            assert!(err.contains("truncated"), "cut {cut}: {err}");
        }
    }

    #[test]
    fn corruption_rejected() {
        let mut bytes = sample().encode();
        let mid = HEADER_LEN + (bytes.len() - HEADER_LEN) / 2;
        bytes[mid] ^= 0x01;
        let err = Snapshot::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("mesp-test-snapshot");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.snap");
        let s = sample();
        let bytes = s.save(&path).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        let back = Snapshot::load(&path).unwrap();
        assert_bitwise_eq(&s, &back);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn train_config_adopts_identity_keeps_wiring() {
        let s = sample();
        let base = crate::config::TrainConfig {
            kernel: crate::config::KernelKind::Naive,
            threads: 3,
            steps: 99,
            ..Default::default()
        };
        let cfg = s.train_config(&base);
        assert_eq!(cfg.method, Method::StoreH);
        assert_eq!(cfg.quant, QuantMode::Q4);
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.kernel, crate::config::KernelKind::Naive);
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.steps, 99, "step target stays the caller's");
    }
}
