//! Little-endian binary codec for snapshot payloads.
//!
//! Deliberately tiny and dependency-free (the offline build has no serde):
//! a `Writer` appends fixed-width little-endian scalars, length-prefixed
//! strings and dtype-tagged tensors to a byte vector; a `Reader` consumes
//! the same sequence, failing loudly (never panicking) on truncation.
//! f32 payloads travel as raw IEEE-754 bit patterns (`to_bits`), so
//! encode → decode is the identity on every value including NaNs — the
//! bitwise-resume guarantee starts here.

use crate::tensor::{DType, Data, HostTensor};

/// FNV-1a 64-bit over a byte slice. Used as the snapshot payload
/// checksum: every step is `h = (h ^ byte) * PRIME` with an odd prime,
/// and multiplication by an odd constant is a bijection on u64, so any
/// single corrupted byte is guaranteed to change the final hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Fold one tensor (dtype tag, shape, raw element bits) into a running
/// FNV-1a state. Shared by the snapshot writer and the model-weights
/// fingerprint so both hash identical byte sequences.
pub fn fnv1a64_tensor(mut h: u64, t: &HostTensor) -> u64 {
    let mut fold = |bytes: &[u8]| {
        for b in bytes {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    fold(&[dtype_tag(t.dtype())]);
    fold(&(t.shape.len() as u32).to_le_bytes());
    for d in &t.shape {
        fold(&(*d as u64).to_le_bytes());
    }
    match &t.data {
        Data::F32(v) => v.iter().for_each(|x| fold(&x.to_bits().to_le_bytes())),
        Data::I32(v) => v.iter().for_each(|x| fold(&x.to_le_bytes())),
        Data::U8(v) => fold(v),
    }
    h
}

fn dtype_tag(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::U8 => 2,
    }
}

fn dtype_from_tag(t: u8) -> anyhow::Result<DType> {
    match t {
        0 => Ok(DType::F32),
        1 => Ok(DType::I32),
        2 => Ok(DType::U8),
        _ => anyhow::bail!("snapshot: unknown dtype tag {t}"),
    }
}

/// Append-only payload builder.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// f32 as its raw bit pattern — bitwise round-trip, NaNs included.
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f32_slice(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        for x in v {
            self.f32(*x);
        }
    }

    pub fn tensor(&mut self, t: &HostTensor) {
        self.u8(dtype_tag(t.dtype()));
        self.u32(t.shape.len() as u32);
        for d in &t.shape {
            self.u64(*d as u64);
        }
        match &t.data {
            Data::F32(v) => v.iter().for_each(|x| {
                self.buf.extend_from_slice(&x.to_bits().to_le_bytes())
            }),
            Data::I32(v) => v.iter().for_each(|x| {
                self.buf.extend_from_slice(&x.to_le_bytes())
            }),
            Data::U8(v) => self.buf.extend_from_slice(v),
        }
    }
}

/// Sequential payload consumer; every accessor fails with a "truncated"
/// error instead of panicking when the payload runs out.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    /// Bytes not yet consumed (0 after a complete decode).
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.remaining() >= n,
            "snapshot payload truncated: need {n} more bytes at offset {}, \
             have {}",
            self.pos,
            self.remaining()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn str(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| anyhow::anyhow!("snapshot: non-UTF-8 string field"))
    }

    pub fn f32_slice(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(4 * n)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    pub fn tensor(&mut self) -> anyhow::Result<HostTensor> {
        let dtype = dtype_from_tag(self.u8()?)?;
        let ndim = self.u32()? as usize;
        anyhow::ensure!(ndim <= 8, "snapshot: implausible tensor rank {ndim}");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u64()? as usize);
        }
        let len: usize = shape.iter().product();
        anyhow::ensure!(
            len.checked_mul(dtype.size()).is_some_and(|b| b <= self.remaining()),
            "snapshot payload truncated inside a tensor of shape {shape:?}"
        );
        Ok(match dtype {
            DType::F32 => {
                let raw = self.take(4 * len)?;
                HostTensor::f32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|c| {
                            f32::from_bits(u32::from_le_bytes(
                                c.try_into().unwrap(),
                            ))
                        })
                        .collect(),
                )
            }
            DType::I32 => {
                let raw = self.take(4 * len)?;
                HostTensor::i32(
                    &shape,
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect(),
                )
            }
            DType::U8 => HostTensor::u8(&shape, self.take(len)?.to_vec()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xdead_beef);
        w.u64(u64::MAX - 3);
        w.f32(f32::NAN);
        w.str("toy");
        w.f32_slice(&[1.5, -0.0, f32::INFINITY]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert!(r.f32().unwrap().is_nan());
        assert_eq!(r.str().unwrap(), "toy");
        let v = r.f32_slice().unwrap();
        assert_eq!(v[0], 1.5);
        assert!(v[1].is_sign_negative() && v[1] == 0.0);
        assert_eq!(v[2], f32::INFINITY);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn tensor_roundtrip_all_dtypes() {
        for t in [
            HostTensor::f32(&[2, 3], vec![0.1, -2.0, f32::MIN, 0.0, 9.0, 1e-40]),
            HostTensor::i32(&[4], vec![-1, 0, i32::MAX, 7]),
            HostTensor::u8(&[3, 2], vec![0, 255, 16, 32, 64, 128]),
        ] {
            let mut w = Writer::new();
            w.tensor(&t);
            let bytes = w.into_bytes();
            let mut r = Reader::new(&bytes);
            let back = r.tensor().unwrap();
            assert_eq!(back.shape, t.shape);
            match (&back.data, &t.data) {
                (Data::F32(a), Data::F32(b)) => {
                    assert!(a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()))
                }
                (Data::I32(a), Data::I32(b)) => assert_eq!(a, b),
                (Data::U8(a), Data::U8(b)) => assert_eq!(a, b),
                _ => panic!("dtype changed"),
            }
            assert_eq!(r.remaining(), 0);
        }
    }

    #[test]
    fn truncation_is_an_error_not_a_panic() {
        let mut w = Writer::new();
        w.tensor(&HostTensor::f32(&[16], vec![1.0; 16]));
        let bytes = w.into_bytes();
        for cut in [0, 1, 5, bytes.len() - 1] {
            let mut r = Reader::new(&bytes[..cut]);
            let err = r.tensor().unwrap_err().to_string();
            assert!(err.contains("truncated"), "cut {cut}: {err}");
        }
    }

    #[test]
    fn fnv_detects_single_byte_flips() {
        let data: Vec<u8> = (0..200u8).collect();
        let h0 = fnv1a64(&data);
        for i in [0usize, 1, 99, 199] {
            let mut d = data.clone();
            d[i] ^= 0x40;
            assert_ne!(fnv1a64(&d), h0, "flip at {i} undetected");
        }
    }

    #[test]
    fn tensor_fingerprint_matches_separate_calls() {
        let a = HostTensor::f32(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        let b = HostTensor::u8(&[2], vec![9, 8]);
        let h1 = fnv1a64_tensor(fnv1a64_tensor(0xcbf29ce484222325, &a), &b);
        let h2 = fnv1a64_tensor(fnv1a64_tensor(0xcbf29ce484222325, &a), &b);
        assert_eq!(h1, h2);
        let c = HostTensor::f32(&[4], vec![1.0, 2.0, 3.0, 4.5]);
        assert_ne!(fnv1a64_tensor(0xcbf29ce484222325, &c), h1);
    }
}
