//! Synthetic training corpus — the WikiText-2 stand-in (DESIGN.md §2).
//!
//! A seeded order-2 Markov chain over a Zipf-distributed vocabulary of
//! word-like strings produces text with realistic token statistics
//! (Zipfian unigram curve, learnable local structure). Loss-curve *shape*
//! comparisons between methods are dataset-agnostic; what matters is that
//! the data has learnable structure so exact-gradient methods visibly
//! outperform MeZO, which this corpus provides. A small embedded English
//! sample is also available for byte-level smoke tests.

use crate::util::Rng;

/// A tiny embedded English corpus for byte-level tests (public-domain
/// text fragments).
pub const TINY_CORPUS: &str = "\
the quick brown fox jumps over the lazy dog. \
it was the best of times, it was the worst of times, it was the age of \
wisdom, it was the age of foolishness. call me ishmael. some years ago, \
never mind how long precisely, having little or no money in my purse, \
and nothing particular to interest me on shore, i thought i would sail \
about a little and see the watery part of the world. in the beginning \
the universe was created. this has made a lot of people very angry and \
been widely regarded as a bad move. all happy families are alike; each \
unhappy family is unhappy in its own way. ";

/// Deterministic synthetic corpus generator.
pub struct CorpusGen {
    words: Vec<String>,
    /// transition[a][k] = (next_word, weight) — sparse order-1 table with
    /// an order-2 perturbation folded into the hash.
    fanout: usize,
    rng: Rng,
}

impl CorpusGen {
    /// `vocab_words` distinct word types, Zipf-distributed.
    pub fn new(seed: u64, vocab_words: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0xc0de);
        let mut words = Vec::with_capacity(vocab_words);
        const SYL: [&str; 16] = [
            "ka", "to", "ri", "mu", "sha", "en", "lo", "vi", "da", "pe",
            "su", "na", "que", "bo", "zi", "tha",
        ];
        for i in 0..vocab_words {
            let n_syl = 1 + (i % 3) + (rng.below(2));
            let mut w = String::new();
            for _ in 0..n_syl {
                w.push_str(SYL[rng.below(SYL.len())]);
            }
            words.push(w);
        }
        CorpusGen { words, fanout: 8, rng }
    }

    /// Zipf sample: P(rank k) ∝ 1/(k+1).
    fn zipf(&mut self) -> usize {
        let n = self.words.len();
        let h_n: f32 = (1..=n).map(|k| 1.0 / k as f32).sum();
        let mut u = self.rng.uniform() * h_n;
        for k in 0..n {
            u -= 1.0 / (k + 1) as f32;
            if u <= 0.0 {
                return k;
            }
        }
        n - 1
    }

    /// Generate `n_words` words of Markov text. Local transitions are a
    /// deterministic function of the previous two words, so the sequence
    /// is highly learnable — loss drops fast under true gradients.
    pub fn generate(&mut self, n_words: usize) -> String {
        let mut out = String::new();
        let (mut prev2, mut prev) = (0usize, 1usize.min(self.words.len() - 1));
        for i in 0..n_words {
            // 20% Zipf restarts keep unigram stats heavy-tailed.
            let next = if self.rng.uniform() < 0.2 {
                self.zipf()
            } else {
                // deterministic sparse successor set of (prev2, prev)
                let h = (prev2 as u64)
                    .wrapping_mul(0x9e3779b97f4a7c15)
                    .wrapping_add(prev as u64)
                    .wrapping_mul(0xbf58476d1ce4e5b9);
                let slot = self.rng.below(self.fanout) as u64;
                ((h >> 17).wrapping_add(slot.wrapping_mul(0x2545f491)))
                    as usize
                    % self.words.len()
            };
            out.push_str(&self.words[next]);
            if i % 13 == 12 {
                out.push('.');
            }
            out.push(' ');
            prev2 = prev;
            prev = next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let a = CorpusGen::new(5, 100).generate(200);
        let b = CorpusGen::new(5, 100).generate(200);
        assert_eq!(a, b);
        let c = CorpusGen::new(6, 100).generate(200);
        assert_ne!(a, c);
    }

    #[test]
    fn zipfian_head_is_heavy() {
        let mut g = CorpusGen::new(1, 200);
        let text = g.generate(8000);
        let mut counts = std::collections::HashMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w.trim_end_matches('.')).or_insert(0usize) += 1;
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = freqs.iter().sum();
        let top10: usize = freqs.iter().take(10).sum();
        // heavy-tailed: top-10 of 200 word types (5% of types) cover a
        // disproportionate share of tokens (uniform would give ~5%)
        assert!(top10 * 6 > total, "top10 {top10} of {total}");
    }

    #[test]
    fn tiny_corpus_nonempty_ascii() {
        assert!(TINY_CORPUS.len() > 500);
        assert!(TINY_CORPUS.is_ascii());
    }
}
