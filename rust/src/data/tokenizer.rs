//! Tokenizers: byte-level (vocab 256) and a greedy word-hash tokenizer for
//! larger vocabularies. The compiled configs have fixed vocab sizes, so
//! the tokenizer must map any text into [0, vocab); both implementations
//! guarantee that invariant (property-tested below and in rust/tests/).

/// Tokenizer trait — the data pipeline is generic over it.
pub trait Tokenizer: Send {
    fn vocab(&self) -> usize;
    fn encode(&self, text: &str) -> Vec<i32>;
    /// Best-effort decode (diagnostics only).
    fn decode(&self, ids: &[i32]) -> String;
}

/// Byte-level tokenizer: one token per byte. Exact roundtrip.
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn vocab(&self) -> usize {
        256
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids.iter().map(|i| (*i & 0xff) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Word-hash tokenizer for vocab > 256: words (and single punctuation
/// bytes) hash into the id space above the 256 byte ids, which remain
/// reserved as a fallback for unknown/rare strings. Deterministic and
/// stateless — adequate for synthetic corpora where exact detokenization
/// does not matter, while exercising a realistic vocab-sized embedding.
pub struct HashWordTokenizer {
    vocab: usize,
}

impl HashWordTokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab > 512, "use ByteTokenizer for small vocabs");
        HashWordTokenizer { vocab }
    }

    fn word_id(&self, w: &str) -> i32 {
        let mut h = 0xcbf29ce484222325u64;
        for b in w.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        (256 + (h % (self.vocab as u64 - 256))) as i32
    }
}

impl Tokenizer for HashWordTokenizer {
    fn vocab(&self) -> usize {
        self.vocab
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for word in text.split_whitespace() {
            let core = word.trim_matches(|c: char| c.is_ascii_punctuation());
            if !core.is_empty() {
                out.push(self.word_id(core));
            }
            for p in word.chars().rev() {
                if p.is_ascii_punctuation() {
                    out.push(p as i32); // punctuation keeps its byte id
                    break;
                }
            }
        }
        out
    }

    fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|i| {
                if *i < 256 {
                    (*i as u8 as char).to_string()
                } else {
                    format!("<w{i}>")
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Pick the right tokenizer for a config's vocab size.
pub fn for_vocab(vocab: usize) -> Box<dyn Tokenizer> {
    if vocab <= 512 {
        Box::new(ByteTokenizer)
    } else {
        Box::new(HashWordTokenizer::new(vocab))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let t = ByteTokenizer;
        let s = "hello, world!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn ids_always_in_vocab() {
        let texts = ["a b c", "héllo wörld", "x.y,z!", ""];
        for v in [1024usize, 16384] {
            let t = HashWordTokenizer::new(v);
            for s in texts {
                for id in t.encode(s) {
                    assert!((0..v as i32).contains(&id), "{id} vocab {v}");
                }
            }
        }
    }

    #[test]
    fn hash_tokenizer_deterministic_and_distinct() {
        let t = HashWordTokenizer::new(4096);
        assert_eq!(t.encode("foo bar"), t.encode("foo bar"));
        let a = t.encode("foo")[0];
        let b = t.encode("bar")[0];
        assert_ne!(a, b);
    }

    #[test]
    fn for_vocab_dispatch() {
        assert_eq!(for_vocab(256).vocab(), 256);
        assert_eq!(for_vocab(16384).vocab(), 16384);
    }

    #[test]
    fn punctuation_preserved() {
        let t = HashWordTokenizer::new(2048);
        let ids = t.encode("stop. go");
        assert!(ids.contains(&('.' as i32)));
    }
}
