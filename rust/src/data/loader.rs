//! Batch loader: tokenizes the corpus stream and serves fixed-shape
//! (tokens, targets) batches, with an optional background prefetch thread.
//!
//! The offline build has no tokio, so prefetch uses a plain thread + a
//! bounded mpsc channel — same backpressure semantics (the producer blocks
//! when `depth` batches are queued), no async runtime on the hot path.
//! Targets are next-token shifted with wraparound on the last position.

use std::sync::mpsc;

use crate::memory::MemoryTracker;
use crate::tensor::HostTensor;
use crate::util::Rng;

use super::corpus::CorpusGen;
use super::tokenizer::Tokenizer;

/// One training batch: tokens + next-token targets, both [batch, seq] i32.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: HostTensor,
    pub targets: HostTensor,
}

impl Batch {
    pub fn bytes(&self) -> u64 {
        self.tokens.bytes() + self.targets.bytes()
    }
}

/// Synchronous batch source over an endless synthetic token stream.
pub struct BatchSource {
    stream: Vec<i32>,
    pos: usize,
    batch: usize,
    seq: usize,
    gen: CorpusGen,
    tokenizer: Box<dyn Tokenizer>,
    rng: Rng,
}

impl BatchSource {
    pub fn new(vocab: usize, batch: usize, seq: usize, seed: u64) -> Self {
        let tokenizer = super::tokenizer::for_vocab(vocab);
        let words = (vocab / 4).clamp(50, 5000);
        BatchSource {
            stream: Vec::new(),
            pos: 0,
            batch,
            seq,
            gen: CorpusGen::new(seed, words),
            tokenizer,
            rng: Rng::new(seed ^ 0xda7a),
        }
    }

    fn refill(&mut self) {
        let text = self.gen.generate(4 * self.batch * self.seq);
        let mut toks = self.tokenizer.encode(&text);
        if toks.is_empty() {
            // pathological tokenizer/corpus combo — fall back to noise
            toks = (0..self.batch * self.seq * 4)
                .map(|_| self.rng.below(self.tokenizer.vocab()) as i32)
                .collect();
        }
        self.stream.extend(toks);
    }

    /// Next fixed-shape batch (deterministic given the seed).
    pub fn next_batch(&mut self) -> Batch {
        let need = self.batch * self.seq + 1;
        while self.stream.len() - self.pos < need {
            self.refill();
        }
        let window = self.stream[self.pos..self.pos + need].to_vec();
        self.pos += self.batch * self.seq;
        // periodically drop consumed prefix to bound memory
        if self.pos > 1 << 20 {
            self.stream.drain(..self.pos);
            self.pos = 0;
        }
        let shape = [self.batch, self.seq];
        let tokens = HostTensor::i32(&shape, window[..need - 1].to_vec());
        let targets = HostTensor::i32(&shape, window[1..].to_vec());
        Batch { tokens, targets }
    }
}

/// Background prefetching loader: a producer thread keeps up to `depth`
/// batches ready; `next()` blocks only when the queue is empty.
///
/// Batches are tracked under "data:batch" from the moment the producer
/// creates them: the guard travels through the channel with its batch,
/// so queued batches (and the one the blocked producer holds) count as
/// live bytes — the inventory `fleet::admission` charges per session.
pub struct PrefetchLoader {
    rx: mpsc::Receiver<(Batch, crate::memory::Guard)>,
    _handle: std::thread::JoinHandle<()>,
}

impl PrefetchLoader {
    pub fn spawn(
        vocab: usize,
        batch: usize,
        seq: usize,
        seed: u64,
        depth: usize,
        tracker: MemoryTracker,
    ) -> Self {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = std::thread::Builder::new()
            .name("batch-prefetch".into())
            .spawn(move || {
                let mut src = BatchSource::new(vocab, batch, seq, seed);
                // blocks when the channel is full (backpressure); exits
                // when the receiver hangs up.
                loop {
                    let b = src.next_batch();
                    let g = tracker.track("data:batch", b.bytes());
                    if tx.send((b, g)).is_err() {
                        break;
                    }
                }
            })
            .expect("spawn prefetch thread");
        PrefetchLoader { rx, _handle: handle }
    }

    /// Receive the next batch with its "data:batch" guard; the bytes
    /// stay live until the caller drops the guard.
    pub fn next(&self) -> (Batch, crate::memory::Guard) {
        self.rx.recv().expect("prefetch thread alive")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_shape_and_range() {
        let mut src = BatchSource::new(256, 2, 16, 3);
        for _ in 0..5 {
            let b = src.next_batch();
            assert_eq!(b.tokens.shape, vec![2, 16]);
            assert_eq!(b.targets.shape, vec![2, 16]);
            assert!(b.tokens.as_i32().iter().all(|t| (0..256).contains(t)));
        }
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut src = BatchSource::new(256, 1, 8, 4);
        let b = src.next_batch();
        let toks = b.tokens.as_i32();
        let tgts = b.targets.as_i32();
        // target[i] == token[i+1] within the window
        for i in 0..7 {
            assert_eq!(tgts[i], toks[i + 1]);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = BatchSource::new(1024, 1, 32, 9);
        let mut b = BatchSource::new(1024, 1, 32, 9);
        assert_eq!(a.next_batch().tokens.as_i32(), b.next_batch().tokens.as_i32());
    }

    #[test]
    fn consecutive_batches_advance() {
        let mut src = BatchSource::new(256, 1, 16, 1);
        let b1 = src.next_batch();
        let b2 = src.next_batch();
        assert_ne!(b1.tokens.as_i32(), b2.tokens.as_i32());
    }

    #[test]
    fn prefetch_loader_delivers() {
        let tr = MemoryTracker::new();
        let loader = PrefetchLoader::spawn(256, 1, 16, 2, 2, tr.clone());
        let (b1, _g1) = loader.next();
        let (b2, _g2) = loader.next();
        assert_eq!(b1.tokens.shape, vec![1, 16]);
        assert_ne!(b1.tokens.as_i32(), b2.tokens.as_i32());
        assert!(tr.live() > 0);
        // matches the synchronous source exactly (same seed)
        let mut sync = BatchSource::new(256, 1, 16, 2);
        assert_eq!(sync.next_batch().tokens.as_i32(), b1.tokens.as_i32());
    }
}
