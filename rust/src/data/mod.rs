//! Data pipeline: synthetic corpus generation (the WikiText-2 stand-in),
//! tokenization, and a backpressured prefetching batch loader.

pub mod corpus;
pub mod loader;
pub mod tokenizer;

pub use corpus::{CorpusGen, TINY_CORPUS};
pub use loader::{Batch, BatchSource, PrefetchLoader};
pub use tokenizer::{ByteTokenizer, HashWordTokenizer, Tokenizer};
