//! Training session coordinator — the L3 top level that wires config →
//! backend → data pipeline → engine → metrics, and the sweep runner the
//! reproduce drivers use to run method grids.
//!
//! Sessions are built through [`TrainSession::builder`]: one entry point
//! covering fresh starts, snapshot resume, caller-supplied trackers,
//! shared [`WeightCache`]s, and telemetry (trace sinks + metrics
//! registries). The old `new` / `with_tracker` / `restore` /
//! `restore_with_tracker` constructor quartet is gone.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::{presets, BackendKind, Method, ModelDims, TrainConfig};
use crate::data::PrefetchLoader;
use crate::fleet::{FleetOptions, Job, JobSpec, Scheduler};
use crate::memory::MemoryTracker;
use crate::metrics::{MetricsLogger, RunSummary};
use crate::model::{ModelSpec, WeightCache};
use crate::obs::{self, MetricsRegistry, TraceSink};
use crate::persist::{RngStreams, Snapshot};
use crate::runtime::{Backend, KernelOptions, ReferenceBackend};
use crate::tensor::DType;
use crate::train::{build_engine, common::EngineCtx, Engine, StepStats};
use crate::util::rng::{derive, stream};

/// Depth of the background batch-prefetch queue every session spawns.
/// Shared with `fleet::admission`'s cost model so admission accounts for
/// the batches a session can hold.
pub const PREFETCH_DEPTH: usize = 4;

/// Instantiate the compute backend a config asks for.
///
/// * [`BackendKind::Reference`] — in-process pure-Rust backend. `dims` is
///   the interned `Arc<ModelDims>` from the session's [`WeightCache`]
///   (the cache owns the geometry and hands out borrows; sessions no
///   longer clone a private `ModelDims` each).
/// * [`BackendKind::Pjrt`] — the PJRT artifact runtime; `dims` is ignored
///   because `artifacts/<config>/manifest.json` is authoritative there
///   (requires the `pjrt` cargo feature and `make artifacts`).
pub fn make_backend(
    cfg: &TrainConfig,
    dims: Arc<ModelDims>,
    tracker: MemoryTracker,
    trace: TraceSink,
) -> anyhow::Result<Arc<dyn Backend>> {
    match cfg.backend {
        BackendKind::Reference => {
            let opts = KernelOptions { kind: cfg.kernel, threads: cfg.threads };
            Ok(Arc::new(
                ReferenceBackend::with_telemetry(dims, tracker, opts, trace)
                    .with_loss_chunk(cfg.loss_chunk),
            ))
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => {
            let _ = dims;
            Ok(Arc::new(crate::runtime::Runtime::load(
                std::path::Path::new(&cfg.artifacts_dir),
                &cfg.config,
                tracker,
            )?))
        }
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => {
            let _ = dims;
            anyhow::bail!(
                "this build has no PJRT support; rebuild with `--features pjrt` \
                 (and run `make artifacts`) or use --backend reference"
            )
        }
    }
}

/// Staged construction of a [`TrainSession`] — the single session entry
/// point. Obtain one via [`TrainSession::builder`], chain the optional
/// knobs, then [`SessionBuilder::build`]:
///
/// ```ignore
/// let sess = TrainSession::builder(cfg)
///     .tracker(aggregate.child())        // roll memory into a parent
///     .weight_cache(cache.clone())       // share frozen base weights
///     .resume_from(&snapshot_path)       // continue a suspended run
///     .build()?;
/// ```
///
/// Defaults: a fresh private [`MemoryTracker`], a private single-session
/// [`WeightCache`] on that tracker (so frozen weights land under
/// `weights:shared` exactly as in the fleet case, just unshared), and a
/// fresh start at step 0.
pub struct SessionBuilder {
    cfg: TrainConfig,
    tracker: Option<MemoryTracker>,
    cache: Option<WeightCache>,
    resume_from: Option<PathBuf>,
    trace: Option<TraceSink>,
    registry: Option<MetricsRegistry>,
}

impl SessionBuilder {
    /// Account the session's memory on a caller-supplied tracker — the
    /// fleet scheduler passes a child of its aggregate tracker here, so
    /// every tensor the session holds also rolls up into the fleet-wide
    /// live total.
    ///
    /// Model init and the data loader draw from independent sub-seeds
    /// derived from `cfg.seed` (`util::rng::derive`), so sessions with
    /// different seeds differ in BOTH weights and data, while two
    /// sessions sharing a seed remain bit-identical (the gradcheck and
    /// Fig-2 equivalence runs rely on that). Pinning `cfg.model_seed`
    /// decouples the two: jobs can share base weights while still
    /// drawing distinct data streams.
    pub fn tracker(mut self, tracker: MemoryTracker) -> Self {
        self.tracker = Some(tracker);
        self
    }

    /// Intern the frozen base weights in `cache` instead of a private
    /// one: sessions whose `(config dims, model seed, quant)` agree
    /// share ONE `Arc<FrozenModel>`, charged once on the cache's tracker
    /// under `weights:shared`. Without this, the session builds (or
    /// re-uses, if the spec is somehow already live) weights through a
    /// private cache on its own tracker.
    pub fn weight_cache(mut self, cache: WeightCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Resume from a snapshot file instead of starting fresh: the
    /// snapshot's identity (config/method/quant/optimizer/lr/seed)
    /// overrides the base config's, the base keeps supplying wiring
    /// (backend/kernel/threads/logging), and every piece of mutable
    /// state — adapters, optimizer moments, step counter, loader
    /// cursor — is restored. The frozen base weights are re-attached
    /// through the weight cache (regenerated only when no live session
    /// already holds them) and verified against the snapshot
    /// fingerprint; a mismatch (different seed derivation, changed init,
    /// different quant packing) refuses to resume instead of training on
    /// silently different weights. The continued run is
    /// bitwise-identical to one that was never suspended.
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume_from = Some(path.into());
        self
    }

    /// Record structured trace events into `trace` — the fleet scheduler
    /// passes a job-scoped handle of its shared sink here. Overrides the
    /// sink that `cfg.trace_path` would otherwise auto-create. Telemetry
    /// is observe-only: traced runs stay bitwise identical to untraced.
    pub fn trace(mut self, trace: TraceSink) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Record step/memory/artifact metrics into a caller-supplied
    /// [`MetricsRegistry`] (the fleet shares one across jobs). Defaults
    /// to a fresh private registry.
    pub fn registry(mut self, registry: MetricsRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Build the session: resolve dims, intern the frozen base in the
    /// weight cache, instantiate the backend, derive this session's
    /// adapters, spawn the data pipeline — and, when resuming, restore
    /// mutable state from the snapshot.
    pub fn build(self) -> anyhow::Result<TrainSession> {
        let tracker = self.tracker.unwrap_or_else(MemoryTracker::new);
        let cache = self
            .cache
            .unwrap_or_else(|| WeightCache::new(tracker.clone()));
        // An explicit sink wins; otherwise `--trace <path>` in the config
        // auto-creates a recording sink that `export_telemetry` writes out.
        let trace = self.trace.unwrap_or_else(|| {
            if self.cfg.trace_path.is_some() {
                TraceSink::enabled()
            } else {
                TraceSink::disabled()
            }
        });
        let registry = self.registry.unwrap_or_default();
        match self.resume_from {
            None => Self::fresh(self.cfg, tracker, &cache, trace, registry),
            Some(path) => {
                Self::resume(&self.cfg, &path, tracker, &cache, trace, registry)
            }
        }
    }

    fn fresh(
        cfg: TrainConfig,
        tracker: MemoryTracker,
        cache: &WeightCache,
        trace: TraceSink,
        registry: MetricsRegistry,
    ) -> anyhow::Result<TrainSession> {
        // Resolve geometry and attach the (possibly shared) frozen base.
        // Reference configs come from the compiled preset table and the
        // backend borrows the cache's interned dims Arc; PJRT reads dims
        // from the artifact manifest, so there the backend exists first
        // and the cache interns under the manifest's geometry.
        let (rt, frozen): (Arc<dyn Backend>, _) = match cfg.backend {
            BackendKind::Reference => {
                let spec = ModelSpec::new(
                    presets::compiled(&cfg.config)?,
                    cfg.model_seed(),
                    cfg.quant,
                );
                let frozen = cache.get_or_build(&spec);
                let rt = make_backend(
                    &cfg,
                    frozen.dims.clone(),
                    tracker.clone(),
                    trace.clone(),
                )?;
                (rt, frozen)
            }
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => {
                let rt: Arc<dyn Backend> =
                    Arc::new(crate::runtime::Runtime::load(
                        std::path::Path::new(&cfg.artifacts_dir),
                        &cfg.config,
                        tracker.clone(),
                    )?);
                let spec = ModelSpec::new(
                    rt.dims().clone(),
                    cfg.model_seed(),
                    cfg.quant,
                );
                let frozen = cache.get_or_build(&spec);
                (rt, frozen)
            }
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => anyhow::bail!(
                "this build has no PJRT support; rebuild with `--features \
                 pjrt` (and run `make artifacts`) or use --backend reference"
            ),
        };
        // Adapters are derivable from the frozen identity alone (an
        // independent RNG fork), so N sessions sharing one FrozenModel
        // still start from identical LoRA state — each copy private, on
        // the session's own tracker.
        let adapters =
            ModelSpec::new(frozen.dims.clone(), frozen.seed, frozen.quant)
                .build_adapters(&tracker);
        let dims = frozen.dims.clone();
        let mut ctx = EngineCtx::new(
            rt, frozen, adapters, cfg.optimizer, cfg.lr, cfg.spill_limit,
            trace.clone(),
        )?;
        ctx.act_compress = cfg.act_compress;
        let engine = build_engine(cfg.method, ctx, cfg.mezo_eps)?;
        let loader = PrefetchLoader::spawn(
            dims.vocab, dims.batch, dims.seq,
            derive(cfg.seed, stream::LOADER), PREFETCH_DEPTH,
            tracker.clone(),
        );
        let metrics = MetricsLogger::new(
            cfg.metrics_path.as_deref().map(std::path::Path::new),
            cfg.log_every,
        )?;
        Ok(TrainSession {
            cfg,
            engine,
            loader,
            metrics,
            tracker,
            trace,
            registry,
            batches_consumed: 0,
        })
    }

    fn resume(
        base: &TrainConfig,
        path: &Path,
        tracker: MemoryTracker,
        cache: &WeightCache,
        trace: TraceSink,
        registry: MetricsRegistry,
    ) -> anyhow::Result<TrainSession> {
        let snap = Snapshot::load(path)?;
        let cfg = snap.train_config(base);
        let streams = RngStreams::derive_from(cfg.seed);
        anyhow::ensure!(
            streams == snap.rng,
            "snapshot RNG stream seeds {:?} disagree with this build's \
             derivation {streams:?} for seed {} — the derive scheme drifted; \
             the resumed data/weight streams would diverge",
            snap.rng,
            cfg.seed
        );
        let mut sess = Self::fresh(cfg, tracker, cache, trace, registry)?;
        {
            let ctx = sess.engine.ctx_mut();
            anyhow::ensure!(
                ctx.weights_fingerprint() == snap.weights_fingerprint,
                "snapshot base-weight fingerprint {:#018x} does not match \
                 the regenerated model's {:#018x} — seed, config dims, init \
                 scheme or quant packing changed since the snapshot",
                snap.weights_fingerprint,
                ctx.weights_fingerprint()
            );
            anyhow::ensure!(
                snap.lora.len() == ctx.adapters.lora.len(),
                "snapshot has {} LoRA layers, model has {}",
                snap.lora.len(),
                ctx.adapters.lora.len()
            );
            for (l, layer) in snap.lora.iter().enumerate() {
                let dst = &mut ctx.adapters.lora[l].tensors;
                anyhow::ensure!(
                    layer.len() == dst.len(),
                    "snapshot layer {l} has {} adapter tensors, model has {}",
                    layer.len(),
                    dst.len()
                );
                for (i, t) in layer.iter().enumerate() {
                    anyhow::ensure!(
                        t.dtype() == DType::F32 && t.shape == dst[i].shape,
                        "snapshot adapter {l}/{i} is {:?} {:?}, model expects \
                         f32 {:?}",
                        t.dtype(),
                        t.shape,
                        dst[i].shape
                    );
                    dst[i].as_f32_mut().copy_from_slice(t.as_f32());
                }
            }
            ctx.opt.import_state(snap.opt_t, &snap.opt_m1, &snap.opt_m2)?;
            ctx.step = snap.step as usize;
        }
        // Fast-forward the deterministic batch stream to the recorded
        // cursor: the next batch the resumed session sees is exactly the
        // one the uninterrupted run would have seen at this step. This
        // replays O(steps) batch generations — a deliberate trade:
        // batch generation is orders of magnitude cheaper than the
        // training steps being restored, and replaying from (seed,
        // count) keeps the snapshot format independent of the loader's
        // internal buffering (stream buffer, tokenizer, corpus RNG).
        for _ in 0..snap.batches_consumed {
            let _ = sess.loader.next();
        }
        sess.batches_consumed = snap.batches_consumed;
        Ok(sess)
    }
}

/// A live training session: one runnable config + one method.
pub struct TrainSession {
    pub cfg: TrainConfig,
    pub engine: Box<dyn Engine>,
    pub loader: PrefetchLoader,
    pub metrics: MetricsLogger,
    pub tracker: MemoryTracker,
    /// The session's trace sink (disabled unless `--trace` was given or a
    /// caller attached one) — shared with the backend and engine spans.
    pub trace: TraceSink,
    /// Step/memory/artifact metrics (possibly shared fleet-wide).
    pub registry: MetricsRegistry,
    /// Batches drawn through [`Self::step_once`] since the deterministic
    /// data stream began — the loader cursor a snapshot records and a
    /// restore fast-forwards past (it survives suspend/resume cycles).
    batches_consumed: u64,
}

impl TrainSession {
    /// Start building a session for `cfg`. See [`SessionBuilder`].
    pub fn builder(cfg: TrainConfig) -> SessionBuilder {
        SessionBuilder {
            cfg,
            tracker: None,
            cache: None,
            resume_from: None,
            trace: None,
            registry: None,
        }
    }

    /// Capture the session's complete mutable state (must be called at a
    /// step boundary — the only time `TrainSession` exposes anyway).
    pub fn snapshot(&self) -> Snapshot {
        let ctx = self.engine.ctx();
        let (opt_t, opt_m1, opt_m2) = ctx.opt.export_state();
        Snapshot {
            config: self.cfg.config.clone(),
            method: self.cfg.method,
            quant: self.cfg.quant,
            optimizer: self.cfg.optimizer,
            lr: self.cfg.lr,
            seed: self.cfg.seed,
            step: ctx.step as u64,
            batches_consumed: self.batches_consumed,
            rng: RngStreams::derive_from(self.cfg.seed),
            weights_fingerprint: ctx.weights_fingerprint(),
            lora: ctx
                .adapters
                .lora
                .iter()
                .map(|l| l.tensors.clone())
                .collect(),
            opt_t,
            opt_m1,
            opt_m2,
        }
    }

    /// Snapshot to `path` (atomic write); returns bytes written.
    pub fn save_snapshot(&self, path: &Path) -> anyhow::Result<u64> {
        self.snapshot().save(path)
    }

    /// Optimization steps completed so far (continues across resume).
    pub fn steps_done(&self) -> usize {
        self.engine.ctx().step
    }

    /// Batches drawn from the data loader so far (the snapshot cursor).
    pub fn batches_consumed(&self) -> u64 {
        self.batches_consumed
    }

    /// Run ONE optimization step: draw a batch, step the engine, record
    /// metrics. The unit the fleet scheduler interleaves with preemption
    /// checks, and the granularity snapshots are taken at.
    pub fn step_once(&mut self) -> anyhow::Result<StepStats> {
        let (batch, _guard) = self.loader.next();
        self.batches_consumed += 1;
        let stats = self.engine.step(&batch)?;
        self.registry.counter_add("step/count", 1);
        self.registry.observe("step/secs", stats.secs);
        self.registry.gauge_set("step/loss", stats.loss);
        self.registry.gauge_set("step/peak_bytes", stats.peak_bytes as f64);
        self.metrics.record(self.engine.name(), &stats)?;
        Ok(stats)
    }

    /// Fold end-of-run observability state into the registry (per-artifact
    /// exec stats, live/peak memory by tag) and write the exports the
    /// config asks for: the Chrome trace to `cfg.trace_path`, the metrics
    /// JSONL snapshot to `cfg.metrics_out`. Cheap no-op when neither flag
    /// was given and the registry is private.
    pub fn export_telemetry(&self) -> anyhow::Result<()> {
        let ctx = self.engine.ctx();
        obs::views::exec_stats_into(&self.registry, &ctx.rt.exec_stats());
        for (tag, bytes) in self.tracker.breakdown() {
            self.registry
                .gauge_set(&format!("memory/live/{tag}"), bytes as f64);
        }
        for (tag, bytes) in self.tracker.tag_peaks() {
            self.registry
                .gauge_set(&format!("memory/peak/{tag}"), bytes as f64);
        }
        self.registry
            .gauge_set("memory/peak_bytes", self.tracker.peak() as f64);
        if let Some(p) = &self.cfg.trace_path {
            self.trace.export_chrome(Path::new(p))?;
        }
        if let Some(p) = &self.cfg.metrics_out {
            self.registry.export_jsonl(Path::new(p))?;
        }
        Ok(())
    }

    /// Run `steps` (more) optimization steps; returns the summary.
    pub fn run(&mut self, steps: usize) -> anyhow::Result<RunSummary> {
        for _ in 0..steps {
            self.step_once()?;
        }
        Ok(self.metrics.summary())
    }

    /// Per-step loss history (Fig-2 data).
    pub fn losses(&self) -> Vec<f64> {
        self.metrics.history.iter().map(|s| s.loss).collect()
    }
}

/// Run the same (config, steps, seed) under several methods — the
/// comparison grids behind Tables 1/5 and Figure 2. Returns
/// (method, summary, losses) triples in the order `methods` was given.
///
/// The grid goes through the fleet scheduler (single worker, unlimited
/// budget): runs stay serial — step-time ratios remain comparable — but
/// every method grid exercises the same queue/admission/report path the
/// `mesp fleet` serving command uses. All jobs share `base.seed`
/// verbatim: the comparisons REQUIRE identical weights and data streams
/// across methods — and they now also share ONE cached copy of the
/// frozen base weights through the scheduler's [`WeightCache`].
pub fn sweep_methods(
    base: &TrainConfig,
    methods: &[Method],
    steps: usize,
) -> anyhow::Result<Vec<(Method, RunSummary, Vec<f64>)>> {
    let jobs: Vec<Job> = methods
        .iter()
        .enumerate()
        .map(|(id, &m)| {
            let mut spec = JobSpec::from_base(base);
            spec.method = m;
            spec.steps = steps;
            Job { id, spec }
        })
        .collect();
    let opts = FleetOptions {
        budget_bytes: u64::MAX,
        workers: 1,
        ..FleetOptions::default()
    };
    let report = Scheduler::run(&opts, base, jobs)?;
    let mut out = Vec::with_capacity(report.outcomes.len());
    for o in report.outcomes {
        let method = o.job.spec.method;
        let r = o.result.map_err(|e| {
            anyhow::anyhow!("{} sweep job failed: {e}", method.name())
        })?;
        out.push((method, r.summary, r.losses));
    }
    Ok(out)
}
