//! Training session coordinator — the L3 top level that wires config →
//! backend → data pipeline → engine → metrics, and the sweep runner the
//! reproduce drivers use to run method grids.

use std::path::Path;
use std::sync::Arc;

use crate::config::{presets, BackendKind, Method, TrainConfig};
use crate::data::PrefetchLoader;
use crate::fleet::{FleetOptions, Job, JobSpec, Scheduler};
use crate::memory::MemoryTracker;
use crate::metrics::{MetricsLogger, RunSummary};
use crate::persist::{RngStreams, Snapshot};
use crate::runtime::{Backend, KernelOptions, ReferenceBackend};
use crate::tensor::DType;
use crate::train::{build_engine, common::EngineCtx, Engine, StepStats};
use crate::util::rng::{derive, stream};

/// Depth of the background batch-prefetch queue every session spawns.
/// Shared with `fleet::admission`'s cost model so admission accounts for
/// the batches a session can hold.
pub const PREFETCH_DEPTH: usize = 4;

/// Instantiate the compute backend a config asks for.
///
/// * [`BackendKind::Reference`] — in-process pure-Rust backend, dims from
///   `presets::compiled`; no files, no toolchain.
/// * [`BackendKind::Pjrt`] — the PJRT artifact runtime, dims from
///   `artifacts/<config>/manifest.json` (requires the `pjrt` cargo
///   feature and `make artifacts`).
pub fn make_backend(
    cfg: &TrainConfig,
    tracker: MemoryTracker,
) -> anyhow::Result<Arc<dyn Backend>> {
    match cfg.backend {
        BackendKind::Reference => {
            let dims = presets::compiled(&cfg.config)?;
            let opts = KernelOptions { kind: cfg.kernel, threads: cfg.threads };
            Ok(Arc::new(ReferenceBackend::with_kernels(dims, tracker, opts)))
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Arc::new(crate::runtime::Runtime::load(
            std::path::Path::new(&cfg.artifacts_dir),
            &cfg.config,
            tracker,
        )?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => anyhow::bail!(
            "this build has no PJRT support; rebuild with `--features pjrt` \
             (and run `make artifacts`) or use --backend reference"
        ),
    }
}

/// A live training session: one runnable config + one method.
pub struct TrainSession {
    pub cfg: TrainConfig,
    pub engine: Box<dyn Engine>,
    pub loader: PrefetchLoader,
    pub metrics: MetricsLogger,
    pub tracker: MemoryTracker,
    /// Batches drawn through [`Self::step_once`] since the deterministic
    /// data stream began — the loader cursor a snapshot records and a
    /// restore fast-forwards past (it survives suspend/resume cycles).
    batches_consumed: u64,
}

impl TrainSession {
    /// Build a session: instantiate the backend, init model, spawn the
    /// data pipeline.
    pub fn new(cfg: TrainConfig) -> anyhow::Result<TrainSession> {
        Self::with_tracker(cfg, MemoryTracker::new())
    }

    /// Build a session on a caller-supplied tracker — the fleet scheduler
    /// passes a child of its aggregate tracker here, so every tensor the
    /// session holds also rolls up into the fleet-wide live total.
    ///
    /// Model init and the data loader draw from independent sub-seeds
    /// derived from `cfg.seed` (`util::rng::derive`), so sessions with
    /// different seeds differ in BOTH weights and data, while two
    /// sessions sharing a seed remain bit-identical (the gradcheck and
    /// Fig-2 equivalence runs rely on that).
    pub fn with_tracker(
        cfg: TrainConfig,
        tracker: MemoryTracker,
    ) -> anyhow::Result<TrainSession> {
        let rt = make_backend(&cfg, tracker.clone())?;
        let dims = rt.dims().clone();
        let ctx = EngineCtx::new(rt, derive(cfg.seed, stream::MODEL),
                                 cfg.optimizer, cfg.lr, cfg.spill_limit,
                                 cfg.quant)?;
        let engine = build_engine(cfg.method, ctx, cfg.mezo_eps)?;
        let loader = PrefetchLoader::spawn(
            dims.vocab, dims.batch, dims.seq,
            derive(cfg.seed, stream::LOADER), PREFETCH_DEPTH,
            tracker.clone(),
        );
        let metrics = MetricsLogger::new(
            cfg.metrics_path.as_deref().map(std::path::Path::new),
            cfg.log_every,
        )?;
        Ok(TrainSession {
            cfg,
            engine,
            loader,
            metrics,
            tracker,
            batches_consumed: 0,
        })
    }

    /// Resume a session from a snapshot file on a fresh tracker. See
    /// [`Self::restore_with_tracker`].
    pub fn restore(base: &TrainConfig, path: &Path) -> anyhow::Result<TrainSession> {
        Self::restore_with_tracker(base, path, MemoryTracker::new())
    }

    /// Resume a suspended session: rebuild it from the snapshot's
    /// identity (config/method/quant/optimizer/lr/seed) on `base`'s
    /// wiring (backend/kernel/threads/logging), then restore every piece
    /// of mutable state — adapters, optimizer moments, step counter,
    /// loader cursor. The frozen base weights are regenerated from the
    /// model stream seed and verified against the snapshot fingerprint;
    /// a mismatch (different seed derivation, changed init, different
    /// quant packing) refuses to resume instead of training on silently
    /// different weights. The continued run is bitwise-identical to one
    /// that was never suspended.
    pub fn restore_with_tracker(
        base: &TrainConfig,
        path: &Path,
        tracker: MemoryTracker,
    ) -> anyhow::Result<TrainSession> {
        let snap = Snapshot::load(path)?;
        let cfg = snap.train_config(base);
        let streams = RngStreams::derive_from(cfg.seed);
        anyhow::ensure!(
            streams == snap.rng,
            "snapshot RNG stream seeds {:?} disagree with this build's \
             derivation {streams:?} for seed {} — the derive scheme drifted; \
             the resumed data/weight streams would diverge",
            snap.rng,
            cfg.seed
        );
        let mut sess = Self::with_tracker(cfg, tracker)?;
        {
            let ctx = sess.engine.ctx_mut();
            anyhow::ensure!(
                ctx.weights_fingerprint() == snap.weights_fingerprint,
                "snapshot base-weight fingerprint {:#018x} does not match \
                 the regenerated model's {:#018x} — seed, config dims, init \
                 scheme or quant packing changed since the snapshot",
                snap.weights_fingerprint,
                ctx.weights_fingerprint()
            );
            anyhow::ensure!(
                snap.lora.len() == ctx.model.lora.len(),
                "snapshot has {} LoRA layers, model has {}",
                snap.lora.len(),
                ctx.model.lora.len()
            );
            for (l, layer) in snap.lora.iter().enumerate() {
                let dst = &mut ctx.model.lora[l].tensors;
                anyhow::ensure!(
                    layer.len() == dst.len(),
                    "snapshot layer {l} has {} adapter tensors, model has {}",
                    layer.len(),
                    dst.len()
                );
                for (i, t) in layer.iter().enumerate() {
                    anyhow::ensure!(
                        t.dtype() == DType::F32 && t.shape == dst[i].shape,
                        "snapshot adapter {l}/{i} is {:?} {:?}, model expects \
                         f32 {:?}",
                        t.dtype(),
                        t.shape,
                        dst[i].shape
                    );
                    dst[i].as_f32_mut().copy_from_slice(t.as_f32());
                }
            }
            ctx.opt.import_state(snap.opt_t, &snap.opt_m1, &snap.opt_m2)?;
            ctx.step = snap.step as usize;
        }
        // Fast-forward the deterministic batch stream to the recorded
        // cursor: the next batch the resumed session sees is exactly the
        // one the uninterrupted run would have seen at this step. This
        // replays O(steps) batch generations — a deliberate trade:
        // batch generation is orders of magnitude cheaper than the
        // training steps being restored, and replaying from (seed,
        // count) keeps the snapshot format independent of the loader's
        // internal buffering (stream buffer, tokenizer, corpus RNG).
        for _ in 0..snap.batches_consumed {
            let _ = sess.loader.next();
        }
        sess.batches_consumed = snap.batches_consumed;
        Ok(sess)
    }

    /// Capture the session's complete mutable state (must be called at a
    /// step boundary — the only time `TrainSession` exposes anyway).
    pub fn snapshot(&self) -> Snapshot {
        let ctx = self.engine.ctx();
        let (opt_t, opt_m1, opt_m2) = ctx.opt.export_state();
        Snapshot {
            config: self.cfg.config.clone(),
            method: self.cfg.method,
            quant: self.cfg.quant,
            optimizer: self.cfg.optimizer,
            lr: self.cfg.lr,
            seed: self.cfg.seed,
            step: ctx.step as u64,
            batches_consumed: self.batches_consumed,
            rng: RngStreams::derive_from(self.cfg.seed),
            weights_fingerprint: ctx.weights_fingerprint(),
            lora: self
                .engine
                .ctx()
                .model
                .lora
                .iter()
                .map(|l| l.tensors.clone())
                .collect(),
            opt_t,
            opt_m1,
            opt_m2,
        }
    }

    /// Snapshot to `path` (atomic write); returns bytes written.
    pub fn save_snapshot(&self, path: &Path) -> anyhow::Result<u64> {
        self.snapshot().save(path)
    }

    /// Optimization steps completed so far (continues across resume).
    pub fn steps_done(&self) -> usize {
        self.engine.ctx().step
    }

    /// Batches drawn from the data loader so far (the snapshot cursor).
    pub fn batches_consumed(&self) -> u64 {
        self.batches_consumed
    }

    /// Run ONE optimization step: draw a batch, step the engine, record
    /// metrics. The unit the fleet scheduler interleaves with preemption
    /// checks, and the granularity snapshots are taken at.
    pub fn step_once(&mut self) -> anyhow::Result<StepStats> {
        let (batch, _guard) = self.loader.next();
        self.batches_consumed += 1;
        let stats = self.engine.step(&batch)?;
        self.metrics.record(self.engine.name(), &stats)?;
        Ok(stats)
    }

    /// Run `steps` (more) optimization steps; returns the summary.
    pub fn run(&mut self, steps: usize) -> anyhow::Result<RunSummary> {
        for _ in 0..steps {
            self.step_once()?;
        }
        Ok(self.metrics.summary())
    }

    /// Per-step loss history (Fig-2 data).
    pub fn losses(&self) -> Vec<f64> {
        self.metrics.history.iter().map(|s| s.loss).collect()
    }
}

/// Run the same (config, steps, seed) under several methods — the
/// comparison grids behind Tables 1/5 and Figure 2. Returns
/// (method, summary, losses) triples in the order `methods` was given.
///
/// The grid goes through the fleet scheduler (single worker, unlimited
/// budget): runs stay serial — step-time ratios remain comparable — but
/// every method grid exercises the same queue/admission/report path the
/// `mesp fleet` serving command uses. All jobs share `base.seed`
/// verbatim: the comparisons REQUIRE identical weights and data streams
/// across methods.
pub fn sweep_methods(
    base: &TrainConfig,
    methods: &[Method],
    steps: usize,
) -> anyhow::Result<Vec<(Method, RunSummary, Vec<f64>)>> {
    let jobs: Vec<Job> = methods
        .iter()
        .enumerate()
        .map(|(id, &m)| {
            let mut spec = JobSpec::from_base(base);
            spec.method = m;
            spec.steps = steps;
            Job { id, spec }
        })
        .collect();
    let opts = FleetOptions {
        budget_bytes: u64::MAX,
        workers: 1,
        ..FleetOptions::default()
    };
    let report = Scheduler::run(&opts, base, jobs)?;
    let mut out = Vec::with_capacity(report.outcomes.len());
    for o in report.outcomes {
        let method = o.job.spec.method;
        let r = o.result.map_err(|e| {
            anyhow::anyhow!("{} sweep job failed: {e}", method.name())
        })?;
        out.push((method, r.summary, r.losses));
    }
    Ok(out)
}
