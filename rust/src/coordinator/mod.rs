//! Training session coordinator — the L3 top level that wires config →
//! backend → data pipeline → engine → metrics, and the sweep runner the
//! reproduce drivers use to run method grids.

use std::sync::Arc;

use crate::config::{presets, BackendKind, Method, TrainConfig};
use crate::data::PrefetchLoader;
use crate::fleet::{FleetOptions, Job, JobSpec, Scheduler};
use crate::memory::MemoryTracker;
use crate::metrics::{MetricsLogger, RunSummary};
use crate::runtime::{Backend, KernelOptions, ReferenceBackend};
use crate::train::{build_engine, common::EngineCtx, Engine};
use crate::util::rng::{derive, stream};

/// Depth of the background batch-prefetch queue every session spawns.
/// Shared with `fleet::admission`'s cost model so admission accounts for
/// the batches a session can hold.
pub const PREFETCH_DEPTH: usize = 4;

/// Instantiate the compute backend a config asks for.
///
/// * [`BackendKind::Reference`] — in-process pure-Rust backend, dims from
///   `presets::compiled`; no files, no toolchain.
/// * [`BackendKind::Pjrt`] — the PJRT artifact runtime, dims from
///   `artifacts/<config>/manifest.json` (requires the `pjrt` cargo
///   feature and `make artifacts`).
pub fn make_backend(
    cfg: &TrainConfig,
    tracker: MemoryTracker,
) -> anyhow::Result<Arc<dyn Backend>> {
    match cfg.backend {
        BackendKind::Reference => {
            let dims = presets::compiled(&cfg.config)?;
            let opts = KernelOptions { kind: cfg.kernel, threads: cfg.threads };
            Ok(Arc::new(ReferenceBackend::with_kernels(dims, tracker, opts)))
        }
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => Ok(Arc::new(crate::runtime::Runtime::load(
            std::path::Path::new(&cfg.artifacts_dir),
            &cfg.config,
            tracker,
        )?)),
        #[cfg(not(feature = "pjrt"))]
        BackendKind::Pjrt => anyhow::bail!(
            "this build has no PJRT support; rebuild with `--features pjrt` \
             (and run `make artifacts`) or use --backend reference"
        ),
    }
}

/// A live training session: one runnable config + one method.
pub struct TrainSession {
    pub cfg: TrainConfig,
    pub engine: Box<dyn Engine>,
    pub loader: PrefetchLoader,
    pub metrics: MetricsLogger,
    pub tracker: MemoryTracker,
}

impl TrainSession {
    /// Build a session: instantiate the backend, init model, spawn the
    /// data pipeline.
    pub fn new(cfg: TrainConfig) -> anyhow::Result<TrainSession> {
        Self::with_tracker(cfg, MemoryTracker::new())
    }

    /// Build a session on a caller-supplied tracker — the fleet scheduler
    /// passes a child of its aggregate tracker here, so every tensor the
    /// session holds also rolls up into the fleet-wide live total.
    ///
    /// Model init and the data loader draw from independent sub-seeds
    /// derived from `cfg.seed` (`util::rng::derive`), so sessions with
    /// different seeds differ in BOTH weights and data, while two
    /// sessions sharing a seed remain bit-identical (the gradcheck and
    /// Fig-2 equivalence runs rely on that).
    pub fn with_tracker(
        cfg: TrainConfig,
        tracker: MemoryTracker,
    ) -> anyhow::Result<TrainSession> {
        let rt = make_backend(&cfg, tracker.clone())?;
        let dims = rt.dims().clone();
        let ctx = EngineCtx::new(rt, derive(cfg.seed, stream::MODEL),
                                 cfg.optimizer, cfg.lr, cfg.spill_limit,
                                 cfg.quant)?;
        let engine = build_engine(cfg.method, ctx, cfg.mezo_eps)?;
        let loader = PrefetchLoader::spawn(
            dims.vocab, dims.batch, dims.seq,
            derive(cfg.seed, stream::LOADER), PREFETCH_DEPTH,
            tracker.clone(),
        );
        let metrics = MetricsLogger::new(
            cfg.metrics_path.as_deref().map(std::path::Path::new),
            cfg.log_every,
        )?;
        Ok(TrainSession { cfg, engine, loader, metrics, tracker })
    }

    /// Run `steps` optimization steps; returns the summary.
    pub fn run(&mut self, steps: usize) -> anyhow::Result<RunSummary> {
        for _ in 0..steps {
            let (batch, _guard) = self.loader.next();
            let stats = self.engine.step(&batch)?;
            self.metrics.record(self.engine.name(), &stats)?;
        }
        Ok(self.metrics.summary())
    }

    /// Per-step loss history (Fig-2 data).
    pub fn losses(&self) -> Vec<f64> {
        self.metrics.history.iter().map(|s| s.loss).collect()
    }
}

/// Run the same (config, steps, seed) under several methods — the
/// comparison grids behind Tables 1/5 and Figure 2. Returns
/// (method, summary, losses) triples in the order `methods` was given.
///
/// The grid goes through the fleet scheduler (single worker, unlimited
/// budget): runs stay serial — step-time ratios remain comparable — but
/// every method grid exercises the same queue/admission/report path the
/// `mesp fleet` serving command uses. All jobs share `base.seed`
/// verbatim: the comparisons REQUIRE identical weights and data streams
/// across methods.
pub fn sweep_methods(
    base: &TrainConfig,
    methods: &[Method],
    steps: usize,
) -> anyhow::Result<Vec<(Method, RunSummary, Vec<f64>)>> {
    let jobs: Vec<Job> = methods
        .iter()
        .enumerate()
        .map(|(id, &m)| {
            let mut spec = JobSpec::from_base(base);
            spec.method = m;
            spec.steps = steps;
            Job { id, spec }
        })
        .collect();
    let opts = FleetOptions { budget_bytes: u64::MAX, workers: 1 };
    let report = Scheduler::run(&opts, base, jobs)?;
    let mut out = Vec::with_capacity(report.outcomes.len());
    for o in report.outcomes {
        let method = o.job.spec.method;
        let r = o.result.map_err(|e| {
            anyhow::anyhow!("{} sweep job failed: {e}", method.name())
        })?;
        out.push((method, r.summary, r.losses));
    }
    Ok(out)
}
