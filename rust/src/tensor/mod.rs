//! Host-side tensors: the interchange type between the coordinator and the
//! PJRT runtime. Activations and parameters live here between executable
//! calls; all heavy math happens inside the AOT-compiled artifacts, so
//! this type only needs shape bookkeeping plus the small host-side ops the
//! optimizer / MeZO / metrics require.

pub mod arena;

pub use arena::{ArenaStats, ScratchBuf, TensorArena};

use crate::util::Rng;

/// Element type of a tensor. Mirrors the `dtype` strings in manifest.json.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u8" => Ok(DType::U8),
            _ => anyhow::bail!("unknown dtype '{s}'"),
        }
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }
}

/// Dense host tensor. Storage is always f32 or i32 vectors; u8 only
/// appears in the quantized-weight path.
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} vs len {}", data.len());
        HostTensor { shape: shape.to_vec(), data: Data::F32(data) }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: Data::I32(data) }
    }

    pub fn u8(shape: &[usize], data: Vec<u8>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: Data::U8(data) }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        HostTensor::f32(shape, vec![0.0; shape.iter().product()])
    }

    /// Seeded N(0, std²) init.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        HostTensor::f32(shape, rng.normal_vec(shape.iter().product(), std))
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
            Data::U8(_) => DType::U8,
        }
    }

    /// Logical size in bytes (what the memory tracker accounts).
    pub fn bytes(&self) -> u64 {
        (self.len() * self.dtype().size()) as u64
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    pub fn as_u8(&self) -> &[u8] {
        match &self.data {
            Data::U8(v) => v,
            _ => panic!("expected u8 tensor"),
        }
    }

    /// First element as f64 — for scalar outputs (loss).
    pub fn scalar(&self) -> f64 {
        self.as_f32()[0] as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_len_bytes() {
        let t = HostTensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.bytes(), 96);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(&[2, 2], vec![0.0; 3]);
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = HostTensor::randn(&[16], 1.0, &mut r1);
        let b = HostTensor::randn(&[16], 1.0, &mut r2);
        assert_eq!(a.as_f32(), b.as_f32());
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::parse("f32").unwrap().size(), 4);
        assert_eq!(DType::parse("u8").unwrap().size(), 1);
        assert!(DType::parse("f64").is_err());
    }
}
