//! Reusable scratch-buffer arena for the kernel engine.
//!
//! Every intermediate the reference backend materializes inside one
//! artifact call — recompute caches, GEMM outputs, packing panels,
//! attention temporaries — is checked out of a [`TensorArena`] instead of
//! being a fresh `Vec` allocation. This buys two things at once:
//!
//! 1. **Reuse** — returned buffers keep their capacity and are handed out
//!    again on the next checkout, so steady-state training stops hitting
//!    the allocator on the hot path.
//! 2. **Accounting** — checked-out bytes are registered with the
//!    session's [`MemoryTracker`] under the `scratch` tag for exactly as
//!    long as they are live, so tracked step peaks (and the fleet's
//!    admission budget, via `memory::model`'s scratch term) include the
//!    working memory that dominates a real on-device backward pass.
//!
//! Buffers that must outlive the call (artifact outputs) escape the pool
//! via [`ScratchBuf::into_vec`]; everything else returns its capacity on
//! drop. The arena is `Sync`: the parallel GEMM kernel checks packing
//! panels out from worker threads. Packing checkouts scale with the
//! active tile profile — `runtime::kernels::tune::Tiles::pack_bound_elems`
//! is the per-thread bound `memory::model` charges, so autotuned tiles
//! move the measured `scratch` tag and the analytical envelope together.

use std::sync::{Arc, Mutex};

use crate::memory::{Guard, MemoryTracker};
use crate::obs::TraceSink;
use crate::util::json::Json;

/// Cumulative arena statistics (observability, not accounting).
#[derive(Debug, Clone, Copy, Default)]
pub struct ArenaStats {
    /// Checkouts served by reusing a pooled buffer.
    pub hits: u64,
    /// Checkouts that had to allocate fresh capacity.
    pub misses: u64,
    /// Bytes currently parked in the pool (idle capacity).
    pub pooled_bytes: u64,
}

#[derive(Debug, Default)]
struct Pool {
    /// Idle buffers, unordered; `take` picks the best capacity fit.
    free: Vec<Vec<f32>>,
    stats: ArenaStats,
}

/// Shared scratch pool. Cheap to clone (one `Arc`).
#[derive(Debug, Clone)]
pub struct TensorArena {
    pool: Arc<Mutex<Pool>>,
    tracker: MemoryTracker,
    /// Checkout/return instants; disabled by default (one branch each).
    trace: TraceSink,
}

impl TensorArena {
    /// An arena whose checkouts are charged to `tracker` under `scratch`.
    pub fn new(tracker: MemoryTracker) -> TensorArena {
        TensorArena {
            pool: Arc::new(Mutex::new(Pool::default())),
            tracker,
            trace: TraceSink::disabled(),
        }
    }

    /// Attach a trace sink: every checkout/return emits an instant event.
    pub fn with_trace(mut self, trace: TraceSink) -> TensorArena {
        self.trace = trace;
        self
    }

    /// Check out a zeroed `len`-element f32 buffer.
    pub fn take(&self, len: usize) -> ScratchBuf {
        let (mut data, hit) = {
            let mut p = self.pool.lock().unwrap();
            // Best-fit: smallest pooled capacity that holds `len`, so one
            // huge buffer is not burned on a tiny checkout.
            let mut best: Option<(usize, usize)> = None; // (index, capacity)
            for (i, v) in p.free.iter().enumerate() {
                if v.capacity() >= len
                    && best.map(|(_, c)| v.capacity() < c).unwrap_or(true)
                {
                    best = Some((i, v.capacity()));
                }
            }
            match best {
                Some((i, _)) => {
                    let v = p.free.swap_remove(i);
                    p.stats.hits += 1;
                    p.stats.pooled_bytes -= (v.capacity() * 4) as u64;
                    (v, true)
                }
                None => {
                    p.stats.misses += 1;
                    (Vec::new(), false)
                }
            }
        };
        data.clear();
        data.resize(len, 0.0);
        if self.trace.is_enabled() {
            self.trace.instant(
                "arena:take",
                "arena",
                vec![
                    ("bytes", Json::Num((len * 4) as f64)),
                    ("hit", Json::Bool(hit)),
                ],
            );
        }
        let guard = self.tracker.track("scratch", (len * 4) as u64);
        ScratchBuf { data, arena: Some(self.clone()), _guard: Some(guard) }
    }

    /// Check out a buffer initialized from a slice.
    pub fn take_from(&self, src: &[f32]) -> ScratchBuf {
        let mut b = self.take(src.len());
        b.copy_from_slice(src);
        b
    }

    fn give_back(&self, data: Vec<f32>) {
        if data.capacity() == 0 {
            return;
        }
        if self.trace.is_enabled() {
            self.trace.instant(
                "arena:return",
                "arena",
                vec![("bytes", Json::Num((data.capacity() * 4) as f64))],
            );
        }
        let mut p = self.pool.lock().unwrap();
        p.stats.pooled_bytes += (data.capacity() * 4) as u64;
        p.free.push(data);
    }

    pub fn stats(&self) -> ArenaStats {
        self.pool.lock().unwrap().stats
    }
}

/// A checked-out scratch buffer: derefs to `[f32]`, returns its capacity
/// to the pool (and its tracked bytes to the tracker) on drop.
#[derive(Debug)]
pub struct ScratchBuf {
    data: Vec<f32>,
    arena: Option<TensorArena>,
    _guard: Option<Guard>,
}

impl ScratchBuf {
    /// Detach the underlying `Vec` for a buffer that escapes the call
    /// (artifact outputs). The scratch bytes are released — the caller
    /// re-tracks them under its own tag — and the capacity permanently
    /// leaves the pool.
    pub fn into_vec(mut self) -> Vec<f32> {
        self.arena = None; // skip give_back in Drop
        let _ = self._guard.take(); // release tracked bytes now
        std::mem::take(&mut self.data)
    }

    /// Return the buffer to the pool NOW, before the owner goes out of
    /// scope; the buffer becomes empty. The fused backward uses this to
    /// free each cached tensor the moment its VJP consumed it — the
    /// paper's "explicitly deallocate all intermediates" discipline, made
    /// visible to the memory tracker.
    pub fn release(&mut self) {
        if let Some(arena) = self.arena.take() {
            arena.give_back(std::mem::take(&mut self.data));
        }
        let _ = self._guard.take();
    }
}

impl Drop for ScratchBuf {
    fn drop(&mut self) {
        if let Some(arena) = self.arena.take() {
            arena.give_back(std::mem::take(&mut self.data));
        }
    }
}

impl std::ops::Deref for ScratchBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl std::ops::DerefMut for ScratchBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_tracks_scratch_bytes() {
        let t = MemoryTracker::new();
        let arena = TensorArena::new(t.clone());
        {
            let b = arena.take(100);
            assert_eq!(b.len(), 100);
            assert!(b.iter().all(|v| *v == 0.0));
            assert_eq!(t.live(), 400);
            assert_eq!(t.breakdown(), vec![("scratch".into(), 400)]);
        }
        assert_eq!(t.live(), 0, "drop releases the tracked bytes");
    }

    #[test]
    fn pool_reuses_capacity() {
        let arena = TensorArena::new(MemoryTracker::new());
        {
            let _a = arena.take(1000);
        }
        assert_eq!(arena.stats().pooled_bytes, 4000);
        {
            let mut b = arena.take(500); // fits in the pooled 1000-cap buf
            b[0] = 1.0;
        }
        let s = arena.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
        {
            let c = arena.take(700);
            assert!(c.iter().all(|v| *v == 0.0), "reused buffers are zeroed");
        }
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let arena = TensorArena::new(MemoryTracker::new());
        {
            let _big = arena.take(10_000);
            let _small = arena.take(128);
        }
        let b = arena.take(64);
        assert!(b.data.capacity() < 10_000, "small checkout must not burn the big buffer");
    }

    #[test]
    fn release_frees_early_and_pools_capacity() {
        let t = MemoryTracker::new();
        let arena = TensorArena::new(t.clone());
        let mut b = arena.take(64);
        b.release();
        assert_eq!(t.live(), 0, "release frees the tracked bytes");
        assert!(b.is_empty(), "released buffer is empty");
        assert_eq!(arena.stats().pooled_bytes, 256);
        b.release(); // idempotent
        drop(b); // and dropping afterwards double-frees nothing
        assert_eq!(arena.stats().pooled_bytes, 256);
    }

    #[test]
    fn into_vec_escapes_the_pool() {
        let t = MemoryTracker::new();
        let arena = TensorArena::new(t.clone());
        let v = arena.take(10).into_vec();
        assert_eq!(v.len(), 10);
        assert_eq!(t.live(), 0, "escaped buffers release their scratch tag");
        assert_eq!(arena.stats().pooled_bytes, 0, "capacity left the pool");
    }

    #[test]
    fn traced_checkouts_emit_instants() {
        let sink = TraceSink::enabled();
        let arena =
            TensorArena::new(MemoryTracker::new()).with_trace(sink.clone());
        {
            let _b = arena.take(16);
        }
        let names: Vec<String> =
            sink.events().into_iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["arena:take", "arena:return"]);
    }

    #[test]
    fn concurrent_checkouts_are_safe() {
        let arena = TensorArena::new(MemoryTracker::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let arena = arena.clone();
                s.spawn(move || {
                    for i in 0..200 {
                        let b = arena.take(64 + i);
                        assert_eq!(b.len(), 64 + i);
                    }
                });
            }
        });
        let s = arena.stats();
        assert_eq!(s.hits + s.misses, 800);
    }
}
