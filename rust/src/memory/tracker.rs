//! Byte-accurate tensor-lifecycle tracker — the reproduction's substitute
//! for the paper's `phys_footprint` measurement (DESIGN.md §2).
//!
//! Every tensor the coordinator holds across executable calls (weights,
//! LoRA params, checkpoints, residuals, gradients, optimizer state, MeZO
//! perturbations, transient call I/O) registers its logical bytes here via
//! an RAII guard; dropping the tensor releases the bytes. Peak live bytes
//! over a step is exactly the quantity the paper's argument is about:
//! which tensors are alive at the worst moment of each strategy.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// Default ring-buffer capacity for the event timeline. At ~1k-5k events
/// per training step on the toy preset this holds tens of steps; older
/// events are dropped oldest-first (see [`MemoryTracker::timeline_dropped`]).
pub const TIMELINE_CAP: usize = 1 << 18;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    /// Monotonic sequence number of the alloc/free.
    pub seq: u64,
    /// Signed byte delta (0 for marker events, e.g. `step:N`).
    pub delta: i64,
    /// Live bytes after applying the delta.
    pub live: u64,
    /// Tag of the alloc/free (`step:N` for step-boundary markers).
    pub tag: String,
    /// True when this event set a new all-time high-water mark.
    pub peak: bool,
}

#[derive(Debug, Default)]
struct Inner {
    live: u64,
    peak: u64,
    seq: u64,
    /// Per-tag live bytes, for breakdown reports.
    tags: std::collections::BTreeMap<String, u64>,
    /// Per-tag high-water marks. Unlike `peak`, never reset: transient
    /// tags (e.g. `scratch`) are usually back to zero live bytes by the
    /// time anyone looks, so their footprint is only visible here.
    tag_peaks: std::collections::BTreeMap<String, u64>,
    /// Optional ring-buffered event timeline (enabled for profile runs).
    timeline: Option<VecDeque<Event>>,
    timeline_cap: usize,
    /// Events evicted from the ring (so truncation is never silent).
    timeline_dropped: u64,
}

fn push_event(g: &mut Inner, ev: Event) {
    let cap = g.timeline_cap;
    let Some(tl) = g.timeline.as_mut() else { return };
    if tl.len() >= cap {
        tl.pop_front();
        g.timeline_dropped += 1;
    }
    tl.push_back(ev);
}

/// Shared tracker handle. Cheap to clone; thread-safe (the data-pipeline
/// thread registers batch buffers concurrently with the trainer).
///
/// Trackers can be chained: [`MemoryTracker::child`] creates a tracker
/// whose every alloc/free is mirrored into its parent, so a fleet-wide
/// aggregate tracker sees the SUM of live bytes across per-session child
/// trackers while each session's own peak/breakdown stays isolated.
#[derive(Debug, Clone, Default)]
pub struct MemoryTracker {
    inner: Arc<Mutex<Inner>>,
    parent: Option<Arc<MemoryTracker>>,
}

impl MemoryTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enable event-timeline recording (off by default: it grows) with
    /// the default ring capacity [`TIMELINE_CAP`].
    pub fn with_timeline() -> Self {
        Self::with_timeline_cap(TIMELINE_CAP)
    }

    /// Enable event-timeline recording with an explicit ring capacity;
    /// once full, the oldest events are evicted (counted in
    /// [`Self::timeline_dropped`]).
    pub fn with_timeline_cap(cap: usize) -> Self {
        let t = Self::new();
        {
            let mut g = t.inner.lock().unwrap();
            g.timeline = Some(VecDeque::new());
            g.timeline_cap = cap.max(1);
        }
        t
    }

    /// A fresh tracker that mirrors every alloc/free into `self` (and
    /// transitively into `self`'s own parents). The child's live/peak/
    /// breakdown describe only its own allocations; the parent's live is
    /// the sum over all children, and the parent's peak is the true
    /// aggregate high-water mark across concurrent children.
    pub fn child(&self) -> MemoryTracker {
        MemoryTracker {
            inner: Arc::default(),
            parent: Some(Arc::new(self.clone())),
        }
    }

    /// Register `bytes` under `tag`; bytes stay live until the returned
    /// guard drops.
    pub fn track(&self, tag: &str, bytes: u64) -> Guard {
        self.apply_alloc(tag, bytes);
        Guard { tracker: self.clone(), tag: tag.to_string(), bytes }
    }

    fn apply_alloc(&self, tag: &str, bytes: u64) {
        {
            let mut guard = self.inner.lock().unwrap();
            let g = &mut *guard;
            g.live += bytes;
            let new_peak = g.live > g.peak;
            g.peak = g.peak.max(g.live);
            g.seq += 1;
            let t = g.tags.entry(tag.to_string()).or_insert(0);
            *t += bytes;
            let t = *t;
            let tp = g.tag_peaks.entry(tag.to_string()).or_insert(0);
            *tp = (*tp).max(t);
            if g.timeline.is_some() {
                let ev = Event {
                    seq: g.seq,
                    delta: bytes as i64,
                    live: g.live,
                    tag: tag.to_string(),
                    peak: new_peak,
                };
                push_event(g, ev);
            }
        }
        if let Some(p) = &self.parent {
            p.apply_alloc(tag, bytes);
        }
    }

    fn release(&self, tag: &str, bytes: u64) {
        {
            let mut guard = self.inner.lock().unwrap();
            let g = &mut *guard;
            // Hard errors, not saturation: an over-release means a guard's
            // bytes were double-freed or mistagged, and letting it clamp
            // to zero would silently corrupt every number downstream
            // (breakdown, admission accounting, the timeline).
            let tag_live = match g.tags.get_mut(tag) {
                None => panic!(
                    "memory tracker: release of {bytes} B under unknown tag \
                     '{tag}' (nothing live under that tag)"
                ),
                Some(t) => t,
            };
            assert!(
                *tag_live >= bytes,
                "memory tracker: release of {bytes} B under tag '{tag}' \
                 exceeds its {tag_live} live B (double free or tag mismatch)"
            );
            *tag_live -= bytes;
            assert!(
                g.live >= bytes,
                "memory tracker: release {bytes} > total live {}",
                g.live
            );
            g.live -= bytes;
            g.seq += 1;
            if g.timeline.is_some() {
                let ev = Event {
                    seq: g.seq,
                    delta: -(bytes as i64),
                    live: g.live,
                    tag: tag.to_string(),
                    peak: false,
                };
                push_event(g, ev);
            }
        }
        if let Some(p) = &self.parent {
            p.release(tag, bytes);
        }
    }

    /// Record a zero-delta marker event (e.g. a step boundary) in the
    /// timeline. No-op unless timeline recording is enabled; never
    /// mirrored into parents (markers are per-session).
    pub fn mark_step(&self, step: u64) {
        let mut guard = self.inner.lock().unwrap();
        let g = &mut *guard;
        if g.timeline.is_none() {
            return;
        }
        g.seq += 1;
        let ev = Event {
            seq: g.seq,
            delta: 0,
            live: g.live,
            tag: format!("step:{step}"),
            peak: false,
        };
        push_event(g, ev);
    }

    pub fn live(&self) -> u64 {
        self.inner.lock().unwrap().live
    }

    pub fn peak(&self) -> u64 {
        self.inner.lock().unwrap().peak
    }

    /// Reset the peak to the current live value (call at step boundaries
    /// to measure per-step peaks).
    pub fn reset_peak(&self) {
        let mut g = self.inner.lock().unwrap();
        g.peak = g.live;
    }

    /// Live bytes per tag (only non-zero tags).
    pub fn breakdown(&self) -> Vec<(String, u64)> {
        self.inner
            .lock()
            .unwrap()
            .tags
            .iter()
            .filter(|(_, v)| **v > 0)
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// Live bytes currently tracked under `tag` (0 for unknown tags).
    pub fn tag_bytes(&self, tag: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .tags
            .get(tag)
            .copied()
            .unwrap_or(0)
    }

    /// High-water mark of live bytes ever reached under `tag` (0 if the
    /// tag was never tracked). Not affected by [`Self::reset_peak`].
    pub fn tag_peak(&self, tag: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .tag_peaks
            .get(tag)
            .copied()
            .unwrap_or(0)
    }

    pub fn timeline(&self) -> Vec<Event> {
        self.inner
            .lock()
            .unwrap()
            .timeline
            .as_ref()
            .map(|tl| tl.iter().cloned().collect())
            .unwrap_or_default()
    }

    /// Number of timeline events evicted from the ring buffer (0 when the
    /// whole run fit, or when the timeline is disabled).
    pub fn timeline_dropped(&self) -> u64 {
        self.inner.lock().unwrap().timeline_dropped
    }

    /// All per-tag high-water marks, sorted by tag.
    pub fn tag_peaks(&self) -> Vec<(String, u64)> {
        self.inner
            .lock()
            .unwrap()
            .tag_peaks
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }
}

/// RAII guard: releases its bytes on drop.
#[derive(Debug)]
pub struct Guard {
    tracker: MemoryTracker,
    tag: String,
    bytes: u64,
}

impl Guard {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for Guard {
    fn drop(&mut self) {
        self.tracker.release(&self.tag, self.bytes);
    }
}

/// A host tensor with its bytes registered in a tracker — the unit the
/// engines store (checkpoints, residuals, grads…).
#[derive(Debug)]
pub struct Tracked<T> {
    pub value: T,
    _guard: Guard,
}

impl<T> Tracked<T> {
    pub fn new(value: T, guard: Guard) -> Self {
        Tracked { value, _guard: guard }
    }
}

impl<T> std::ops::Deref for Tracked<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_bytes_tracks_per_tag_live() {
        let t = MemoryTracker::new();
        let a = t.track("w", 100);
        let b = t.track("w", 20);
        let _c = t.track("x", 7);
        assert_eq!(t.tag_bytes("w"), 120);
        assert_eq!(t.tag_bytes("x"), 7);
        assert_eq!(t.tag_bytes("nope"), 0);
        drop(b);
        assert_eq!(t.tag_bytes("w"), 100);
        drop(a);
        assert_eq!(t.tag_bytes("w"), 0);
    }

    #[test]
    fn live_and_peak() {
        let t = MemoryTracker::new();
        let a = t.track("a", 100);
        assert_eq!(t.live(), 100);
        {
            let _b = t.track("b", 50);
            assert_eq!(t.live(), 150);
            assert_eq!(t.peak(), 150);
        }
        assert_eq!(t.live(), 100);
        assert_eq!(t.peak(), 150, "peak survives frees");
        drop(a);
        assert_eq!(t.live(), 0);
    }

    #[test]
    fn reset_peak_to_live() {
        let t = MemoryTracker::new();
        let _a = t.track("a", 10);
        {
            let _b = t.track("b", 90);
        }
        t.reset_peak();
        assert_eq!(t.peak(), 10);
    }

    #[test]
    fn breakdown_by_tag() {
        let t = MemoryTracker::new();
        let _a = t.track("ckpt", 100);
        let _b = t.track("ckpt", 20);
        let _c = t.track("grads", 7);
        let bd = t.breakdown();
        assert_eq!(bd, vec![("ckpt".into(), 120), ("grads".into(), 7)]);
    }

    #[test]
    fn tag_peaks_survive_release_and_reset() {
        let t = MemoryTracker::new();
        {
            let _a = t.track("scratch", 64);
            let _b = t.track("scratch", 36);
        }
        t.reset_peak();
        assert_eq!(t.tag_peak("scratch"), 100, "peak spans both guards");
        assert_eq!(t.live(), 0);
        assert_eq!(t.tag_peak("never"), 0);
    }

    #[test]
    fn timeline_records_events() {
        let t = MemoryTracker::with_timeline();
        {
            let _a = t.track("x", 5);
        }
        let tl = t.timeline();
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].delta, 5);
        assert_eq!(tl[0].tag, "x");
        assert!(tl[0].peak, "first alloc sets the high-water mark");
        assert_eq!(tl[1].delta, -5);
        assert_eq!(tl[1].live, 0);
        assert!(!tl[1].peak);
        assert_eq!(t.timeline_dropped(), 0);
    }

    #[test]
    fn timeline_marks_step_boundaries() {
        let t = MemoryTracker::with_timeline();
        let _a = t.track("x", 8);
        t.mark_step(3);
        let tl = t.timeline();
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[1].tag, "step:3");
        assert_eq!(tl[1].delta, 0);
        assert_eq!(tl[1].live, 8);
        // markers are a no-op when the timeline is off
        let off = MemoryTracker::new();
        off.mark_step(1);
        assert!(off.timeline().is_empty());
    }

    #[test]
    fn timeline_ring_drops_oldest() {
        let t = MemoryTracker::with_timeline_cap(3);
        for i in 1..=4u64 {
            let _g = t.track("x", i); // each loop: one alloc + one free
        }
        let tl = t.timeline();
        assert_eq!(tl.len(), 3);
        assert_eq!(t.timeline_dropped(), 5, "8 events into a 3-ring");
        assert_eq!(tl.last().unwrap().delta, -4, "newest survives");
        assert!(tl[0].seq < tl[1].seq && tl[1].seq < tl[2].seq);
    }

    #[test]
    fn tag_peaks_lists_all_tags() {
        let t = MemoryTracker::new();
        {
            let _a = t.track("a", 10);
            let _b = t.track("b", 20);
        }
        assert_eq!(
            t.tag_peaks(),
            vec![("a".to_string(), 10), ("b".to_string(), 20)]
        );
    }

    #[test]
    fn release_of_unknown_tag_is_an_error() {
        let t = MemoryTracker::new();
        let known = t.track("known", 4);
        let err = std::panic::catch_unwind(|| t.release("never-tracked", 4));
        assert!(err.is_err(), "unknown-tag release must not saturate");
        // The caught panic poisoned the mutex; leak the guard so its Drop
        // doesn't re-panic on the poisoned lock.
        std::mem::forget(known);
    }

    #[test]
    fn over_release_of_tag_is_an_error() {
        let t = MemoryTracker::new();
        // Two tags live so total `live` (12) exceeds the over-released
        // amount — only the per-tag check can catch this.
        let a = t.track("a", 4);
        let b = t.track("b", 8);
        let err = std::panic::catch_unwind(|| t.release("a", 6));
        assert!(err.is_err(), "tag over-release must not saturate");
        std::mem::forget(a);
        std::mem::forget(b);
    }

    #[test]
    fn child_mirrors_into_parent() {
        let parent = MemoryTracker::new();
        let a = parent.child();
        let b = parent.child();
        let _ga = a.track("x", 100);
        {
            let _gb = b.track("y", 50);
            assert_eq!(parent.live(), 150, "parent sums children");
            assert_eq!(a.live(), 100, "children stay isolated");
            assert_eq!(b.live(), 50);
        }
        assert_eq!(parent.live(), 100);
        assert_eq!(parent.peak(), 150, "parent peak spans both children");
        assert_eq!(a.peak(), 100, "child peak is its own");
        drop(_ga);
        assert_eq!(parent.live(), 0);
    }

    #[test]
    fn grandchild_cascades_to_root() {
        let root = MemoryTracker::new();
        let mid = root.child();
        let leaf = mid.child();
        let _g = leaf.track("z", 7);
        assert_eq!(leaf.live(), 7);
        assert_eq!(mid.live(), 7);
        assert_eq!(root.live(), 7);
    }

    #[test]
    fn threaded_consistency() {
        let t = MemoryTracker::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        let _g = t.track("w", 3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.live(), 0);
        assert!(t.peak() >= 3);
    }
}
