//! Byte-accurate tensor-lifecycle tracker — the reproduction's substitute
//! for the paper's `phys_footprint` measurement (DESIGN.md §2).
//!
//! Every tensor the coordinator holds across executable calls (weights,
//! LoRA params, checkpoints, residuals, gradients, optimizer state, MeZO
//! perturbations, transient call I/O) registers its logical bytes here via
//! an RAII guard; dropping the tensor releases the bytes. Peak live bytes
//! over a step is exactly the quantity the paper's argument is about:
//! which tensors are alive at the worst moment of each strategy.

use std::sync::{Arc, Mutex};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Monotonic sequence number of the alloc/free.
    pub seq: u64,
    /// Signed byte delta.
    pub delta: i64,
    /// Live bytes after applying the delta.
    pub live: u64,
}

#[derive(Debug, Default)]
struct Inner {
    live: u64,
    peak: u64,
    seq: u64,
    /// Per-tag live bytes, for breakdown reports.
    tags: std::collections::BTreeMap<String, u64>,
    /// Per-tag high-water marks. Unlike `peak`, never reset: transient
    /// tags (e.g. `scratch`) are usually back to zero live bytes by the
    /// time anyone looks, so their footprint is only visible here.
    tag_peaks: std::collections::BTreeMap<String, u64>,
    /// Optional event timeline (enabled for memory-profile runs).
    timeline: Option<Vec<Event>>,
}

/// Shared tracker handle. Cheap to clone; thread-safe (the data-pipeline
/// thread registers batch buffers concurrently with the trainer).
///
/// Trackers can be chained: [`MemoryTracker::child`] creates a tracker
/// whose every alloc/free is mirrored into its parent, so a fleet-wide
/// aggregate tracker sees the SUM of live bytes across per-session child
/// trackers while each session's own peak/breakdown stays isolated.
#[derive(Debug, Clone, Default)]
pub struct MemoryTracker {
    inner: Arc<Mutex<Inner>>,
    parent: Option<Arc<MemoryTracker>>,
}

impl MemoryTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enable event-timeline recording (off by default: it grows).
    pub fn with_timeline() -> Self {
        let t = Self::new();
        t.inner.lock().unwrap().timeline = Some(Vec::new());
        t
    }

    /// A fresh tracker that mirrors every alloc/free into `self` (and
    /// transitively into `self`'s own parents). The child's live/peak/
    /// breakdown describe only its own allocations; the parent's live is
    /// the sum over all children, and the parent's peak is the true
    /// aggregate high-water mark across concurrent children.
    pub fn child(&self) -> MemoryTracker {
        MemoryTracker {
            inner: Arc::default(),
            parent: Some(Arc::new(self.clone())),
        }
    }

    /// Register `bytes` under `tag`; bytes stay live until the returned
    /// guard drops.
    pub fn track(&self, tag: &str, bytes: u64) -> Guard {
        self.apply_alloc(tag, bytes);
        Guard { tracker: self.clone(), tag: tag.to_string(), bytes }
    }

    fn apply_alloc(&self, tag: &str, bytes: u64) {
        {
            let mut g = self.inner.lock().unwrap();
            g.live += bytes;
            g.peak = g.peak.max(g.live);
            g.seq += 1;
            let t = g.tags.entry(tag.to_string()).or_insert(0);
            *t += bytes;
            let t = *t;
            let tp = g.tag_peaks.entry(tag.to_string()).or_insert(0);
            *tp = (*tp).max(t);
            let ev = Event { seq: g.seq, delta: bytes as i64, live: g.live };
            if let Some(tl) = g.timeline.as_mut() {
                tl.push(ev);
            }
        }
        if let Some(p) = &self.parent {
            p.apply_alloc(tag, bytes);
        }
    }

    fn release(&self, tag: &str, bytes: u64) {
        {
            let mut g = self.inner.lock().unwrap();
            debug_assert!(g.live >= bytes, "release {bytes} > live {}", g.live);
            g.live = g.live.saturating_sub(bytes);
            g.seq += 1;
            if let Some(t) = g.tags.get_mut(tag) {
                *t = t.saturating_sub(bytes);
            }
            let ev = Event { seq: g.seq, delta: -(bytes as i64), live: g.live };
            if let Some(tl) = g.timeline.as_mut() {
                tl.push(ev);
            }
        }
        if let Some(p) = &self.parent {
            p.release(tag, bytes);
        }
    }

    pub fn live(&self) -> u64 {
        self.inner.lock().unwrap().live
    }

    pub fn peak(&self) -> u64 {
        self.inner.lock().unwrap().peak
    }

    /// Reset the peak to the current live value (call at step boundaries
    /// to measure per-step peaks).
    pub fn reset_peak(&self) {
        let mut g = self.inner.lock().unwrap();
        g.peak = g.live;
    }

    /// Live bytes per tag (only non-zero tags).
    pub fn breakdown(&self) -> Vec<(String, u64)> {
        self.inner
            .lock()
            .unwrap()
            .tags
            .iter()
            .filter(|(_, v)| **v > 0)
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// Live bytes currently tracked under `tag` (0 for unknown tags).
    pub fn tag_bytes(&self, tag: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .tags
            .get(tag)
            .copied()
            .unwrap_or(0)
    }

    /// High-water mark of live bytes ever reached under `tag` (0 if the
    /// tag was never tracked). Not affected by [`Self::reset_peak`].
    pub fn tag_peak(&self, tag: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .tag_peaks
            .get(tag)
            .copied()
            .unwrap_or(0)
    }

    pub fn timeline(&self) -> Vec<Event> {
        self.inner
            .lock()
            .unwrap()
            .timeline
            .clone()
            .unwrap_or_default()
    }
}

/// RAII guard: releases its bytes on drop.
#[derive(Debug)]
pub struct Guard {
    tracker: MemoryTracker,
    tag: String,
    bytes: u64,
}

impl Guard {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for Guard {
    fn drop(&mut self) {
        self.tracker.release(&self.tag, self.bytes);
    }
}

/// A host tensor with its bytes registered in a tracker — the unit the
/// engines store (checkpoints, residuals, grads…).
#[derive(Debug)]
pub struct Tracked<T> {
    pub value: T,
    _guard: Guard,
}

impl<T> Tracked<T> {
    pub fn new(value: T, guard: Guard) -> Self {
        Tracked { value, _guard: guard }
    }
}

impl<T> std::ops::Deref for Tracked<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_bytes_tracks_per_tag_live() {
        let t = MemoryTracker::new();
        let a = t.track("w", 100);
        let b = t.track("w", 20);
        let _c = t.track("x", 7);
        assert_eq!(t.tag_bytes("w"), 120);
        assert_eq!(t.tag_bytes("x"), 7);
        assert_eq!(t.tag_bytes("nope"), 0);
        drop(b);
        assert_eq!(t.tag_bytes("w"), 100);
        drop(a);
        assert_eq!(t.tag_bytes("w"), 0);
    }

    #[test]
    fn live_and_peak() {
        let t = MemoryTracker::new();
        let a = t.track("a", 100);
        assert_eq!(t.live(), 100);
        {
            let _b = t.track("b", 50);
            assert_eq!(t.live(), 150);
            assert_eq!(t.peak(), 150);
        }
        assert_eq!(t.live(), 100);
        assert_eq!(t.peak(), 150, "peak survives frees");
        drop(a);
        assert_eq!(t.live(), 0);
    }

    #[test]
    fn reset_peak_to_live() {
        let t = MemoryTracker::new();
        let _a = t.track("a", 10);
        {
            let _b = t.track("b", 90);
        }
        t.reset_peak();
        assert_eq!(t.peak(), 10);
    }

    #[test]
    fn breakdown_by_tag() {
        let t = MemoryTracker::new();
        let _a = t.track("ckpt", 100);
        let _b = t.track("ckpt", 20);
        let _c = t.track("grads", 7);
        let bd = t.breakdown();
        assert_eq!(bd, vec![("ckpt".into(), 120), ("grads".into(), 7)]);
    }

    #[test]
    fn tag_peaks_survive_release_and_reset() {
        let t = MemoryTracker::new();
        {
            let _a = t.track("scratch", 64);
            let _b = t.track("scratch", 36);
        }
        t.reset_peak();
        assert_eq!(t.tag_peak("scratch"), 100, "peak spans both guards");
        assert_eq!(t.live(), 0);
        assert_eq!(t.tag_peak("never"), 0);
    }

    #[test]
    fn timeline_records_events() {
        let t = MemoryTracker::with_timeline();
        {
            let _a = t.track("x", 5);
        }
        let tl = t.timeline();
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].delta, 5);
        assert_eq!(tl[1].delta, -5);
        assert_eq!(tl[1].live, 0);
    }

    #[test]
    fn child_mirrors_into_parent() {
        let parent = MemoryTracker::new();
        let a = parent.child();
        let b = parent.child();
        let _ga = a.track("x", 100);
        {
            let _gb = b.track("y", 50);
            assert_eq!(parent.live(), 150, "parent sums children");
            assert_eq!(a.live(), 100, "children stay isolated");
            assert_eq!(b.live(), 50);
        }
        assert_eq!(parent.live(), 100);
        assert_eq!(parent.peak(), 150, "parent peak spans both children");
        assert_eq!(a.peak(), 100, "child peak is its own");
        drop(_ga);
        assert_eq!(parent.live(), 0);
    }

    #[test]
    fn grandchild_cascades_to_root() {
        let root = MemoryTracker::new();
        let mid = root.child();
        let leaf = mid.child();
        let _g = leaf.track("z", 7);
        assert_eq!(leaf.live(), 7);
        assert_eq!(mid.live(), 7);
        assert_eq!(root.live(), 7);
    }

    #[test]
    fn threaded_consistency() {
        let t = MemoryTracker::new();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let t = t.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        let _g = t.track("w", 3);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.live(), 0);
        assert!(t.peak() >= 3);
    }
}
