//! Analytical peak-memory model: a per-strategy inventory of every tensor
//! class live at the worst moment of a training step (DESIGN.md §7).
//!
//! This is how the paper's Qwen-scale tables are regenerated on a testbed
//! that cannot run a 3B model: the same inventory drives both
//!   (a) "paper widths" — bf16 activations, f32 grads, int4 base weights
//!       excluded (file-backed mmap is not part of phys_footprint, which
//!       is why the paper's 0.5B MeSP peak of 136 MB is *below* the 247 MB
//!       the quantized base weights alone occupy), and
//!   (b) "tracked widths" — everything f32, matching what the Rust
//!       engines actually hold; integration tests assert the tracker's
//!       measured peak agrees with this mode on real toy/small runs.
//!
//! The peak moment per strategy:
//!   exact-grad methods: max(loss-head phase, worst single block backward)
//!   MeZO:               second perturbed forward (z + perturbation state
//!                       live alongside inference activations).

use crate::config::{ActCompress, Method, ModelDims, OptimizerKind, QuantMode, PROJS};
use crate::model::{actquant, quant};

/// Run-shape options that move the analytical peak: the loss-head chunk
/// size (`--loss-chunk`, 0 = unchunked) and buffered-activation
/// compression (`--act-compress`). Defaults reproduce the paper's
/// configuration exactly, so [`peak_q`] (which forwards defaults) and the
/// pinned paper-width tables are unchanged.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemOptions {
    pub loss_chunk: usize,
    pub act_compress: ActCompress,
}

/// Byte widths per tensor class. The two instantiations are
/// `Widths::paper()` and `Widths::tracked()`.
#[derive(Debug, Clone, Copy)]
pub struct Widths {
    /// Activations / checkpoints / residuals.
    pub act: u64,
    /// Logits + loss-head tensors.
    pub logits: u64,
    /// Gradient buffers.
    pub grad: u64,
    /// LoRA parameters.
    pub lora: u64,
    /// MeZO perturbation state.
    pub z: u64,
    /// Reference-backend kernel scratch (arena checkouts: the recompute
    /// cache and GEMM working buffers materialized inside one artifact
    /// call). 0 at paper widths: the paper's fused on-device kernels do
    /// not materialize this cache — its transient story is already the
    /// minimal/working sets above — so the regenerated tables stay
    /// faithful to the paper's measurements.
    pub scratch: u64,
    /// Fixed runtime overhead (allocator, executables, caches).
    pub runtime_const: u64,
}

impl Widths {
    /// The paper's setup: bf16 activations/params, f32 grads/optimizer,
    /// ~24 MB of framework floor (MLX allocator + compiled functions).
    pub fn paper() -> Widths {
        Widths { act: 2, logits: 2, grad: 4, lora: 2, z: 4, scratch: 0,
                 runtime_const: 24 << 20 }
    }

    /// What the Rust engines hold: all host tensors are f32; no fixed
    /// floor (the tracker only counts tensors, not the allocator); kernel
    /// scratch at f32 width, since the tracker now sees the arena.
    pub fn tracked() -> Widths {
        Widths { act: 4, logits: 4, grad: 4, lora: 4, z: 4, scratch: 4,
                 runtime_const: 0 }
    }
}

/// One strategy's peak-memory breakdown, in bytes.
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    pub lora_params: u64,
    pub optimizer_state: u64,
    pub checkpoints: u64,
    pub loss_head: u64,
    pub block_intermediates: u64,
    pub grad_buffers: u64,
    pub perturbation: u64,
    pub stored_h: u64,
    /// Reference-backend kernel scratch: the arena's worst-case checkout
    /// (recompute cache + backward working buffers + GEMM packing panels)
    /// during the deepest artifact call. Tracked under the `scratch` tag
    /// at run time; 0 at paper widths.
    pub scratch: u64,
    /// On-the-fly dequantization buffers for the int4 base weights: the
    /// paper's setup (§4.5) keeps base weights 4-bit and dequantizes
    /// during compute. Exact-gradient methods re-materialize a FULL
    /// block's weights during that block's backward (the recompute touches
    /// every projection); inference-only forwards (MeZO) dequantize
    /// per-projection, so only the largest projection is live. This is
    /// the model-size-dependent term behind the paper's observation that
    /// MeSP's reduction shrinks from 62% → 42% as models grow (§5.2).
    pub dequant_buffers: u64,
    pub runtime: u64,
}

impl Breakdown {
    pub fn total(&self) -> u64 {
        self.lora_params
            + self.optimizer_state
            + self.checkpoints
            + self.loss_head
            + self.block_intermediates
            + self.grad_buffers
            + self.perturbation
            + self.stored_h
            + self.scratch
            + self.dequant_buffers
            + self.runtime
    }

    pub fn rows(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("lora_params", self.lora_params),
            ("optimizer_state", self.optimizer_state),
            ("checkpoints", self.checkpoints),
            ("loss_head", self.loss_head),
            ("block_intermediates", self.block_intermediates),
            ("grad_buffers", self.grad_buffers),
            ("perturbation", self.perturbation),
            ("stored_h", self.stored_h),
            ("scratch", self.scratch),
            ("dequant_buffers", self.dequant_buffers),
            ("runtime", self.runtime),
        ]
    }
}

// ------------------------------------------------------------- inventories
/// Appendix-E minimal set MeSP keeps while backward-ing one block:
/// normed input h1, attention probs, pre-MLP normed h2, gate output.
fn minimal_set(d: &ModelDims) -> u64 {
    let m = d.m() as u64;
    let probs = (d.batch * d.n_heads * d.seq * d.seq) as u64;
    m * d.d_model as u64            // h1
        + probs                     // attention probs
        + m * d.d_model as u64      // h2
        + m * d.d_ff as u64         // gate_out
}

/// Transient working set of MeSP's fused recompute-backward (tensors that
/// coexist with the minimal set at the worst instant inside one block):
/// attn_flat, silu/up outs, q/k/v heads, plus g_x/g_y ping-pong buffers.
fn mesp_working_set(d: &ModelDims) -> u64 {
    let m = d.m() as u64;
    m * d.q_dim() as u64                        // attn_flat
        + 2 * m * d.d_ff as u64                 // silu_out, up_out
        + m * (d.q_dim() + 2 * d.kv_dim()) as u64 // q, k, v
        + 2 * m * d.d_model as u64              // g_y, g_x
}

/// The residual set MeBP's framework autodiff saves when re-running a
/// checkpointed block (mirrors python model.py::RESIDUALS exactly).
fn residual_set(d: &ModelDims) -> u64 {
    let m = d.m() as u64;
    let probs = (d.batch * d.n_heads * d.seq * d.seq) as u64;
    let h_all: u64 = PROJS.len() as u64 * m * d.rank as u64;
    4 * m * d.d_model as u64                    // x, h1, h2, x2
        + m * d.q_dim() as u64                  // q_rope
        + 2 * m * d.kv_dim() as u64             // k_rope, v_heads
        + probs
        + m * d.q_dim() as u64                  // attn_flat
        + 3 * m * d.d_ff as u64                 // gate, up, silu
        + h_all
}

/// Framework slack: tensors autodiff retains *beyond* the mathematically
/// necessary residuals (projection outputs, pre-softmax scores, LoRA
/// delta outputs, RoPE temporaries) — the paper's §3.3 critique.
fn framework_slack(d: &ModelDims) -> u64 {
    let m = d.m() as u64;
    let probs = (d.batch * d.n_heads * d.seq * d.seq) as u64;
    let proj_outs: u64 = PROJS
        .iter()
        .map(|p| m * d.proj_dims(p).1 as u64)
        .sum();
    proj_outs                                   // xW0 + sxAB per site
        + proj_outs                             // LoRA delta (s·xAB) per site
        + probs                                 // pre-softmax scores
        + 2 * m * d.q_dim() as u64              // rope temporaries
        + 2 * m * d.d_model as u64              // g_y, g_x
}

/// Inference-time transient of one block (MeZO's forward working set).
fn inference_set(d: &ModelDims) -> u64 {
    let m = d.m() as u64;
    let probs = (d.batch * d.n_heads * d.seq * d.seq) as u64;
    m * d.d_model as u64                        // h1 / h2 reuse
        + m * (d.q_dim() + 2 * d.kv_dim()) as u64
        + probs
        + 2 * m * d.d_ff as u64                 // gate, up
        + m * d.d_model as u64                  // block output
}

// ------------------------------------------- reference-backend scratch
//
// The reference backend materializes every intermediate of a block call
// in its TensorArena (tracked as `scratch`), so the tracked-widths
// prediction must bound the arena's worst concurrent checkout. These
// inventories deliberately over-bound by ~2× — they must stay upper
// bounds for admission across all runnable configs — and are identical
// in structure for every exact-gradient method (MeBP's residual-forward
// call materializes the same cache the MeSP fused call does).

/// The full `BlockCache` one forward materializes: the residual set plus
/// the block output `y`.
fn reference_cache(d: &ModelDims) -> u64 {
    residual_set(d) + d.m() as u64 * d.d_model as u64
}

/// Transients that coexist with the cache during the forward half
/// (pre-split q/k/v, LoRA delta buffer, residual adds).
fn reference_fwd_extra(d: &ModelDims) -> u64 {
    let m = d.m() as u64;
    2 * m * (d.q_dim() + 2 * d.kv_dim()) as u64 + m * d.d_ff as u64
        + 2 * m * d.d_model as u64
}

/// Transients that coexist with the cache during the backward half
/// (SwiGLU grads, scaled-g buffers, attention grads + rope/merge
/// temporaries, gx/gw pairs, softmax-VJP tiles, LoRA-rank buffers).
fn reference_bwd_extra(d: &ModelDims) -> u64 {
    let m = d.m() as u64;
    let probs = (d.batch * d.n_heads * d.seq * d.seq) as u64;
    4 * m * d.d_ff as u64
        + 3 * m * (d.q_dim() + 2 * d.kv_dim()) as u64
        + 8 * m * d.d_model as u64
        + 2 * probs
        + 16 * m * d.rank as u64
}

/// GEMM packing panels: each thread of the parallel kernel checks out at
/// most one A panel + one B slab (`Tiles::pack_bound_elems` of the
/// active tile profile, in f32 elements); bound by the machine's core
/// count since admission runs before the fleet scheduler fixes the
/// per-job thread budget.
fn reference_packing(_d: &ModelDims) -> u64 {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1) as u64;
    threads * crate::runtime::kernels::tune::active_tiles().pack_bound_elems() as u64
}

/// Worst-case arena checkout during one BLOCK call of `method`. Loss
/// calls never overlap with block calls; their scratch is charged in
/// full by the `loss_head` term (in-place logits at `w.logits` width +
/// backend temporaries at `w.scratch` width), so this term is the
/// block-phase bound plus the GEMM packing panels.
fn reference_scratch(method: Method, d: &ModelDims) -> u64 {
    let block = match method {
        // fused backward: full cache + backward working set in one call
        Method::Mesp | Method::StoreH | Method::Mebp => {
            reference_cache(d)
                + reference_fwd_extra(d).max(reference_bwd_extra(d))
        }
        // inference forwards only, but each still materializes the cache
        Method::Mezo => reference_cache(d) + reference_fwd_extra(d),
    };
    block + reference_packing(d)
}

/// Reference-backend loss-GRAD temporaries beyond the in-place logits
/// tile the `loss_head` term charges at `w.logits` width (derived from
/// `refmath::lm_loss_grad{,_chunked}` buffer lifetimes, as an upper
/// bound over their three phases):
///
/// * unchunked oracle — worst phase is `logits + g_logits` live together
///   (the 2×-logits reality the model used to miss): one extra logits
///   buffer; the `g_hn + g_h` tail needs `2·m·d`.
/// * chunked — the persistent `g_hn [m,d]` plus the chunk's `hn`/`g_hn`
///   tiles, all ≤ `2·m·d`; the `tile×vocab` logits are charged in-place.
///
/// `max(tile_logits, 2·m·d)` covers every phase of both shapes.
fn reference_loss_grad_extra(d: &ModelDims, tile_logits: u64) -> u64 {
    let m = d.m() as u64;
    tile_logits.max(2 * m * d.d_model as u64)
}

/// Allocator bucket granularity: the paper's measured store-h overhead
/// (Table 5: ~30 MB for 252 tensors of 4 KB) implies the runtime rounds
/// small live buffers up to ~128 KB buckets; we model stored h the same
/// way so the Table-5 delta is comparable.
const ALLOC_BUCKET: u64 = 128 << 10;

/// Always-resident base-weight bytes of one reference-backend session:
/// embedding + final norm + every block's frozen weights, at the given
/// resident precision. Under [`QuantMode::Q4`] the seven projection
/// matrices stay int4-packed (`quant::quantized_bytes`: nibbles + group
/// scales ≈ 0.56 B/param) while norm gains and the tied embedding stay
/// f32 — this is the resident term `fleet::admission` charges ONCE per
/// distinct weight class (`(config, model seed, quant)`): jobs sharing a
/// base attach to one cached `FrozenModel`, so only the first holder
/// pays this, and q4 packing still shrinks what that one copy costs.
pub fn resident_weight_bytes(d: &ModelDims, quant_mode: QuantMode) -> u64 {
    let emb = (d.vocab * d.d_model + d.d_model) as u64 * 4;
    let per_block: u64 = match quant_mode {
        QuantMode::F32 => d.frozen_params_per_block() as u64 * 4,
        QuantMode::Q4 => quant::packed_block_bytes(d),
    };
    emb + per_block * d.n_layers as u64
}

/// Analytical size of one serialized session snapshot
/// (`crate::persist`): the LoRA adapters plus the optimizer's moment
/// slots, all f32. The fixed header and per-tensor shape prefixes are
/// O(100) bytes per tensor and excluded; `tests/persist.rs` asserts the
/// real file stays within a small envelope of this number. Fleet
/// operators size `--snapshot-dir` storage with it: a parked job holds
/// exactly one snapshot on disk (charged to the `snapshot` tracker tag
/// while parked).
pub fn snapshot_bytes(d: &ModelDims, opt: OptimizerKind) -> u64 {
    (4 * d.lora_params_total() * (1 + opt.state_slots())) as u64
}

/// Peak-memory breakdown for `method` at dims `d` (f32-resident weights;
/// see [`peak_q`] for the quant-aware variant).
pub fn peak(method: Method, d: &ModelDims, opt: OptimizerKind, w: Widths) -> Breakdown {
    peak_q(method, d, opt, w, QuantMode::F32)
}

/// Quant-aware peak breakdown at default [`MemOptions`] (unchunked loss,
/// uncompressed residuals).
pub fn peak_q(
    method: Method,
    d: &ModelDims,
    opt: OptimizerKind,
    w: Widths,
    quant_mode: QuantMode,
) -> Breakdown {
    peak_opts(method, d, opt, w, quant_mode, MemOptions::default())
}

/// The full model. Quant-awareness: the activation inventory is identical
/// in both modes (LoRA math and intermediates are f32 either way); q4
/// adds one scratch term: the naive-oracle kernel host-dequantizes a FULL
/// projection matrix into arena scratch per GEMM, so the bound must
/// cover the largest frozen matrix (the fused tiled/parallel kernels
/// need only their packing panels, which are already charged).
///
/// The `loss_head` term splits by width class: the in-place logits tile
/// (`tile × vocab`, where tile = `loss_chunk` or the full `m`) is the
/// algorithmic cost every implementation pays and is charged at
/// `w.logits`; the reference backend's extra loss-phase temporaries —
/// the oracle's separate `g_logits` buffer (the 2×-logits bug this term
/// used to omit) and the `g_hn`/`g_h` tiles — are charged at `w.scratch`
/// (0 at paper widths, so the pinned tables are untouched).
///
/// `act_compress: int8` replaces store-h's per-site f32 buffers with one
/// packed per-layer blob (i8 payload + group scales + outlier pairs —
/// `actquant::compressed_bytes_bound`). MeBP's residual term is NOT
/// reduced: the engine decompresses a full layer's residuals back to f32
/// for the backward call, so compression only shrinks the held window,
/// never MeBP's peak.
pub fn peak_opts(
    method: Method,
    d: &ModelDims,
    opt: OptimizerKind,
    w: Widths,
    quant_mode: QuantMode,
    opts: MemOptions,
) -> Breakdown {
    let m = d.m() as u64;
    let lora = d.lora_params_total() as u64;
    let logits = m * d.vocab as u64;
    // Rows of logits live at once in the loss head: the chunk tile, or
    // the whole sequence when unchunked (loss_chunk == 0).
    let tile = match opts.loss_chunk {
        0 => m,
        c => (c as u64).min(m),
    };
    let tile_logits = tile * d.vocab as u64;
    let loss_extra = reference_loss_grad_extra(d, tile_logits);
    let ckpt = (d.n_layers as u64 + 1) * m * d.d_model as u64;
    let grads_block = d.lora_params_per_block() as u64;
    let block_weights = d.frozen_params_per_block() as u64;
    let largest_proj = PROJS
        .iter()
        .map(|p| {
            let (din, dout) = d.proj_dims(p);
            (din * dout) as u64
        })
        .max()
        .unwrap();

    let mut b = Breakdown {
        lora_params: lora * w.lora,
        optimizer_state: lora * opt.state_slots() as u64 * 4,
        scratch: reference_scratch(method, d) * w.scratch,
        runtime: w.runtime_const,
        ..Default::default()
    };
    if quant_mode == QuantMode::Q4 {
        // The naive-q4 oracle's full-matrix host-dequant buffer (one
        // projection at a time, arena `scratch` tag). 0 at paper widths.
        b.scratch += largest_proj * w.scratch;
    }

    match method {
        Method::Mesp | Method::StoreH => {
            b.checkpoints = ckpt * w.act;
            // Manual CE over the live logits tile: the chunked path forms
            // g_logits in place per chunk; the unchunked oracle holds the
            // full logits plus the [m] log-normalizer column. The
            // reference backend's extra grad-path temporaries (the
            // oracle's SEPARATE g_logits buffer — the 2×-logits peak the
            // one-buffer claim here used to miss — and the g_hn/g_h
            // tiles) are charged at scratch width.
            b.loss_head =
                tile_logits * w.logits + m * 4 + loss_extra * w.scratch;
            b.block_intermediates =
                (minimal_set(d) + mesp_working_set(d)) * w.act;
            b.grad_buffers = grads_block * w.grad;
            b.dequant_buffers = block_weights * w.act;
            if method == Method::StoreH {
                b.stored_h = match opts.act_compress {
                    // h = xA stored for all 7 sites of all layers
                    // (Table 5), each rounded to the allocator bucket.
                    ActCompress::None => {
                        let one_h =
                            (m * d.rank as u64 * w.act).max(ALLOC_BUCKET);
                        (d.n_layers * PROJS.len()) as u64 * one_h
                    }
                    // All 7 sites packed into ONE int8 blob per layer
                    // (payload + group scales + outlier pairs): fewer
                    // bucket-rounded buffers AND ~4× fewer payload bytes.
                    // Width-independent — the packed format is bytes on
                    // the host either way.
                    ActCompress::Int8 => {
                        let elems = PROJS.len() as u64 * m * d.rank as u64;
                        d.n_layers as u64
                            * actquant::compressed_bytes_bound(elems)
                                .max(ALLOC_BUCKET)
                    }
                };
            }
        }
        Method::Mebp => {
            b.checkpoints = ckpt * w.act;
            // Autodiff CE retains logits, the log-normalizer broadcast,
            // softmax probs and g_logits as separate buffers (mx.grad
            // cannot update in place) — 4 logits-sized tensors live
            // unchunked. Under --loss-chunk the manual call shrinks to
            // its tile but the modeled framework slack (2 logits) stays.
            b.loss_head = (2 * logits + 2 * tile_logits) * w.logits
                + loss_extra * w.scratch;
            b.block_intermediates =
                (residual_set(d) + framework_slack(d)) * w.act;
            b.grad_buffers = grads_block * w.grad;
            b.dequant_buffers = block_weights * w.act;
        }
        Method::Mezo => {
            // No checkpoints; the live set is one block's inference
            // transients + the loss evaluation (the live logits tile + the
            // logsumexp temporary — even a fused CE materializes both),
            // plus the normed-hidden tile at scratch width on the
            // reference backend.
            b.loss_head = 2 * tile_logits * w.logits
                + m * d.d_model as u64 * w.scratch;
            b.block_intermediates = inference_set(d) * w.act;
            // z, the +ε parameter copy, and the gradient-scale scratch all
            // live across both forwards (the MLX implementation the paper
            // measures keeps them materialized; Table 4's rank-32 blow-up).
            b.perturbation = 3 * lora * w.z;
            // inference dequantizes per-projection: largest matrix only
            b.dequant_buffers = largest_proj * w.act;
        }
    }
    b
}

/// Convenience: peak bytes at paper widths (what the tables report).
pub fn peak_bytes(method: Method, d: &ModelDims) -> u64 {
    peak(method, d, OptimizerKind::Sgd, Widths::paper()).total()
}

/// Reduction vs MeBP in percent (the paper's headline metric).
pub fn reduction_vs_mebp(method: Method, d: &ModelDims) -> f64 {
    let base = peak_bytes(Method::Mebp, d) as f64;
    let ours = peak_bytes(method, d) as f64;
    100.0 * (1.0 - ours / base)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn d05() -> ModelDims {
        presets::qwen25_05b(256, 8)
    }

    #[test]
    fn ordering_mesp_mezo_mebp() {
        // The paper's core claim at every scale: MeSP < MeZO < MeBP.
        for d in [presets::qwen25_05b(256, 8), presets::qwen25_15b(256, 8),
                  presets::qwen25_3b(256, 8)] {
            let mesp = peak_bytes(Method::Mesp, &d);
            let mezo = peak_bytes(Method::Mezo, &d);
            let mebp = peak_bytes(Method::Mebp, &d);
            assert!(mesp < mezo, "{}: {mesp} !< {mezo}", d.name);
            assert!(mezo < mebp, "{}: {mezo} !< {mebp}", d.name);
        }
    }

    #[test]
    fn storeh_above_mesp_below_mebp() {
        let d = presets::qwen25_3b(256, 8);
        let mesp = peak_bytes(Method::Mesp, &d);
        let sh = peak_bytes(Method::StoreH, &d);
        let mebp = peak_bytes(Method::Mebp, &d);
        assert!(mesp < sh && sh < mebp);
    }

    #[test]
    fn mesp_reduction_in_paper_band() {
        // Table 1: 42-62% across model sizes at seq 256. Allow slack: the
        // substrate differs, the *band* is the claim.
        for (d, lo, hi) in [
            (presets::qwen25_05b(256, 8), 35.0, 75.0),
            (presets::qwen25_15b(256, 8), 30.0, 70.0),
            (presets::qwen25_3b(256, 8), 25.0, 65.0),
        ] {
            let r = reduction_vs_mebp(Method::Mesp, &d);
            assert!((lo..hi).contains(&r), "{}: {r:.1}%", d.name);
        }
    }

    #[test]
    fn mezo_rank_sensitivity() {
        // Table 4: MeZO's reduction deteriorates with rank (larger z).
        let r8 = reduction_vs_mebp(Method::Mezo, &presets::qwen25_05b(256, 8));
        let r32 = reduction_vs_mebp(Method::Mezo, &presets::qwen25_05b(256, 32));
        assert!(r32 < r8, "r32 {r32:.1}% !< r8 {r8:.1}%");
    }

    #[test]
    fn mesp_rank_stability() {
        // Table 4: MeSP's reduction is stable across ranks (±8 pts).
        let r4 = reduction_vs_mebp(Method::Mesp, &presets::qwen25_05b(256, 4));
        let r32 = reduction_vs_mebp(Method::Mesp, &presets::qwen25_05b(256, 32));
        assert!((r4 - r32).abs() < 8.0, "r4 {r4:.1} vs r32 {r32:.1}");
    }

    #[test]
    fn memory_scales_with_seq() {
        // Table 2: MeBP grows ~linearly in seq; MeSP stays below it.
        let m128 = peak_bytes(Method::Mebp, &presets::qwen25_05b(128, 8));
        let m1024 = peak_bytes(Method::Mebp, &presets::qwen25_05b(1024, 8));
        assert!(m1024 > 5 * m128, "{m128} -> {m1024}");
        for seq in [128, 256, 512, 1024] {
            let d = presets::qwen25_05b(seq, 8);
            assert!(peak_bytes(Method::Mesp, &d) < peak_bytes(Method::Mebp, &d));
        }
    }

    #[test]
    fn breakdown_total_is_sum_of_rows() {
        let b = peak(Method::Mebp, &d05(), OptimizerKind::Sgd, Widths::paper());
        let sum: u64 = b.rows().iter().map(|(_, v)| v).sum();
        assert_eq!(sum, b.total());
    }

    #[test]
    fn tracked_widths_all_f32() {
        let w = Widths::tracked();
        assert_eq!((w.act, w.logits, w.grad, w.lora), (4, 4, 4, 4));
        assert_eq!(w.runtime_const, 0);
        assert_eq!(w.scratch, 4, "tracked widths must charge kernel scratch");
    }

    #[test]
    fn scratch_tracked_but_not_in_paper_tables() {
        use crate::config::presets::compiled;
        let d = compiled("toy").unwrap();
        for m in Method::ALL {
            let tracked = peak(m, &d, OptimizerKind::Sgd, Widths::tracked());
            assert!(tracked.scratch > 0, "{}: tracked scratch missing", m.name());
            let paper = peak(m, &d, OptimizerKind::Sgd, Widths::paper());
            assert_eq!(paper.scratch, 0, "paper tables must not change");
        }
        // the fused-backward scratch (cache + bwd working set) exceeds the
        // forward-only scratch at equal dims
        let mesp = peak(Method::Mesp, &d, OptimizerKind::Sgd, Widths::tracked());
        let mezo = peak(Method::Mezo, &d, OptimizerKind::Sgd, Widths::tracked());
        assert!(mesp.scratch >= mezo.scratch);
    }

    #[test]
    fn q4_residents_well_under_half_of_f32() {
        use crate::config::presets::compiled;
        for name in ["toy", "small", "e2e100m"] {
            let d = compiled(name).unwrap();
            let f = resident_weight_bytes(&d, QuantMode::F32);
            let q = resident_weight_bytes(&d, QuantMode::Q4);
            assert!(q < f / 2, "{name}: q4 residents {q} !< f32 {f} / 2");
            // packed blocks alone are ~0.56 B/param; the f32 embedding
            // keeps the total above the naive 1/8 ratio
            assert!(q > f / 10, "{name}: q4 residents {q} implausibly small");
        }
        // q4 applies to the Qwen sim presets too (group-divisible dims)
        let d = presets::qwen25_05b(256, 8);
        assert!(resident_weight_bytes(&d, QuantMode::Q4)
            < resident_weight_bytes(&d, QuantMode::F32) / 2);
    }

    #[test]
    fn q4_scratch_adds_the_oracle_dequant_buffer() {
        use crate::config::presets::compiled;
        let d = compiled("toy").unwrap();
        let f32_peak =
            peak_q(Method::Mesp, &d, OptimizerKind::Sgd, Widths::tracked(),
                   QuantMode::F32);
        let q4_peak =
            peak_q(Method::Mesp, &d, OptimizerKind::Sgd, Widths::tracked(),
                   QuantMode::Q4);
        assert!(q4_peak.scratch > f32_peak.scratch);
        // paper-width tables must not move under q4 (scratch width 0)
        let paper_f32 =
            peak_q(Method::Mesp, &d, OptimizerKind::Sgd, Widths::paper(),
                   QuantMode::F32);
        let paper_q4 =
            peak_q(Method::Mesp, &d, OptimizerKind::Sgd, Widths::paper(),
                   QuantMode::Q4);
        assert_eq!(paper_f32.total(), paper_q4.total());
    }

    #[test]
    fn loss_head_covers_the_two_buffer_grad_reality() {
        // The headline bug: lm_loss_grad holds logits AND a separate
        // g_logits at its peak, but the old model charged one buffer.
        // At tracked widths the term must now cover 2× logits.
        use crate::config::presets::compiled;
        for name in ["toy", "longctx"] {
            let d = compiled(name).unwrap();
            let logits_bytes = d.m() as u64 * d.vocab as u64 * 4;
            for m in [Method::Mesp, Method::StoreH] {
                let b = peak_q(m, &d, OptimizerKind::Sgd, Widths::tracked(),
                               QuantMode::F32);
                assert!(
                    b.loss_head >= 2 * logits_bytes,
                    "{name}/{}: loss_head {} < 2x logits {}",
                    m.name(), b.loss_head, 2 * logits_bytes
                );
            }
            // paper widths keep the in-place single-buffer charge: the
            // backend-extra part rides on the scratch width (0 on paper)
            let p = peak_q(Method::Mesp, &d, OptimizerKind::Sgd,
                           Widths::paper(), QuantMode::F32);
            assert_eq!(p.loss_head, logits_bytes / 2 + d.m() as u64 * 4);
        }
    }

    #[test]
    fn loss_chunk_shrinks_the_loss_head() {
        use crate::config::presets::compiled;
        let d = compiled("longctx").unwrap();
        let full = peak_opts(Method::Mesp, &d, OptimizerKind::Sgd,
                             Widths::tracked(), QuantMode::F32,
                             MemOptions::default());
        let chunked = peak_opts(Method::Mesp, &d, OptimizerKind::Sgd,
                                Widths::tracked(), QuantMode::F32,
                                MemOptions { loss_chunk: 64,
                                             ..Default::default() });
        assert!(
            chunked.loss_head * 4 <= full.loss_head,
            "chunk 64 must cut the tracked loss head >=4x: {} vs {}",
            chunked.loss_head, full.loss_head
        );
        // every method's loss head is monotone in the chunk size
        for m in Method::ALL {
            let at = |c: usize| {
                peak_opts(m, &d, OptimizerKind::Sgd, Widths::tracked(),
                          QuantMode::F32,
                          MemOptions { loss_chunk: c, ..Default::default() })
                .loss_head
            };
            assert!(at(64) <= at(256) && at(256) <= at(0), "{}", m.name());
        }
    }

    #[test]
    fn peak_q_is_peak_opts_at_defaults() {
        let d = d05();
        for m in Method::ALL {
            assert_eq!(
                peak_q(m, &d, OptimizerKind::Sgd, Widths::paper(),
                       QuantMode::F32).total(),
                peak_opts(m, &d, OptimizerKind::Sgd, Widths::paper(),
                          QuantMode::F32, MemOptions::default()).total()
            );
        }
    }

    #[test]
    fn int8_act_compress_shrinks_stored_h_only_for_storeh() {
        use crate::config::presets::compiled;
        let d = compiled("longctx").unwrap();
        let opts = |ac| MemOptions { act_compress: ac, ..Default::default() };
        let f32_sh = peak_opts(Method::StoreH, &d, OptimizerKind::Sgd,
                               Widths::tracked(), QuantMode::F32,
                               opts(ActCompress::None));
        let i8_sh = peak_opts(Method::StoreH, &d, OptimizerKind::Sgd,
                              Widths::tracked(), QuantMode::F32,
                              opts(ActCompress::Int8));
        assert!(
            i8_sh.stored_h * 2 <= f32_sh.stored_h,
            "int8 stored_h {} !<= half of f32 {}",
            i8_sh.stored_h, f32_sh.stored_h
        );
        // MeSP stores no h: the option must not move its breakdown
        let mesp_f32 = peak_opts(Method::Mesp, &d, OptimizerKind::Sgd,
                                 Widths::tracked(), QuantMode::F32,
                                 opts(ActCompress::None));
        let mesp_i8 = peak_opts(Method::Mesp, &d, OptimizerKind::Sgd,
                                Widths::tracked(), QuantMode::F32,
                                opts(ActCompress::Int8));
        assert_eq!(mesp_f32.total(), mesp_i8.total());
    }

    #[test]
    fn adam_state_increases_total() {
        let d = d05();
        let sgd = peak(Method::Mesp, &d, OptimizerKind::Sgd, Widths::paper());
        let adam = peak(Method::Mesp, &d,
                        OptimizerKind::parse("adam").unwrap(), Widths::paper());
        assert!(adam.total() > sgd.total());
    }
}
