//! Memory subsystem: the runtime tensor-lifecycle tracker (the measured
//! substitute for the paper's `phys_footprint`) and the analytical peak
//! model that regenerates the paper's Qwen-scale tables. See DESIGN.md §7.

pub mod model;
pub mod tracker;

pub use model::{
    peak, peak_bytes, peak_q, reduction_vs_mebp, resident_weight_bytes,
    snapshot_bytes, Breakdown, Widths,
};
pub use tracker::{Event, Guard, MemoryTracker, Tracked};
