//! On-device memory budget scenario: the paper's motivating constraint is
//! 6-12 GB shared with the OS and other apps. This example trains under
//! an explicit checkpoint budget — when block checkpoints exceed it, the
//! CheckpointStore spills the oldest ones to disk and reloads them during
//! the reverse sweep (an extension the paper's unified-memory runtime
//! would need; §4.3's lifecycle discipline makes it trivial to add
//! because checkpoints are the ONLY cross-block state).
//!
//!     cargo run --release --example ondevice_budget -- [budget_bytes]

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::util::stats::fmt_mb;

fn main() -> anyhow::Result<()> {
    let budget: u64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(48 * 1024); // deliberately tiny: forces spills on `small`

    for (label, spill) in [("unbounded", 0u64), ("budgeted", budget)] {
        let cfg = TrainConfig {
            config: "small".into(),
            method: Method::Mesp,
            steps: 5,
            spill_limit: spill,
            log_every: usize::MAX,
            ..Default::default()
        };
        let mut sess = TrainSession::builder(cfg).build()?;
        let summary = sess.run(5)?;
        println!(
            "{label:<10} ckpt-budget {:>10}  peak {:>7} MB  {:.1} ms/step  \
             final loss {:.4}",
            if spill == 0 { "∞".into() } else { format!("{spill} B") },
            fmt_mb(summary.peak_bytes),
            summary.mean_step_secs * 1000.0,
            summary.final_loss,
        );
    }
    println!("\nSame losses, lower RAM peak, extra step time — the \
              recompute-vs-store tradeoff extended to storage.");
    Ok(())
}
