//! Quickstart: fine-tune a tiny LoRA-adapted transformer with MeSP.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the minimal public-API path: TrainConfig →
//! TrainSession::builder → run → summary, plus a peek at the per-step
//! memory the paper is about. The builder is the single entry point for
//! every session variant — chain `.tracker(..)`, `.weight_cache(..)` or
//! `.resume_from(..)` before `.build()` when you need them.

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::util::stats::fmt_mb;

fn main() -> anyhow::Result<()> {
    let cfg = TrainConfig {
        config: "toy".into(),       // artifacts/toy — compiled by `make artifacts`
        method: Method::Mesp,       // the paper's contribution
        steps: 30,
        lr: 5e-3,
        seed: 42,
        log_every: 5,
        ..Default::default()
    };
    let steps = cfg.steps;

    println!("== MeSP quickstart: toy model, {steps} steps ==\n");
    let mut sess = TrainSession::builder(cfg).build()?;
    let summary = sess.run(steps)?;

    println!("\nloss: {:.4} -> {:.4}", sess.losses()[0], summary.final_loss);
    println!("peak tracked memory: {} MB", fmt_mb(summary.peak_bytes));
    println!("step time: {:.1} ms (p50)", summary.p50_step_secs * 1000.0);

    println!("\nwhere the memory lives right now (params + prefetched");
    println!("batches — all intermediates were freed block-by-block):");
    for (tag, bytes) in sess.tracker.breakdown() {
        println!("  {tag:<20} {:>10} bytes", bytes);
    }
    Ok(())
}
