//! Regenerate EVERY table and figure of the paper in one run (the
//! EXPERIMENTS.md source). Equivalent to `mesp reproduce --all` but as a
//! library example, with the step counts used for the recorded results.
//!
//!     cargo run --release --example paper_tables -- [out.md]

use mesp::reproduce;

fn main() -> anyhow::Result<()> {
    let out_path = std::env::args().nth(1);
    let mut out = String::new();
    for (n, steps) in [
        (1usize, 5usize), // Table 1 (timing columns measured @small)
        (2, 0), (3, 0), (4, 0),
        (5, 5),           // Table 5 (timing measured @small)
        (6, 0), (7, 0), (8, 0), (9, 0), (10, 0),
        (11, 120),        // Fig 2 / Table 11 (loss curves @small)
    ] {
        eprintln!("[paper_tables] generating table {n} ...");
        let s = reproduce::run_table(n, steps.max(1))?;
        println!("{s}");
        out.push_str(&s);
        out.push('\n');
    }
    if let Some(p) = out_path {
        std::fs::write(&p, &out)?;
        eprintln!("[paper_tables] written to {p}");
    }
    Ok(())
}
