//! End-to-end validation run (DESIGN.md §2): train the ~98M-parameter
//! `e2e100m` config (d=768, 12 blocks, GQA, SwiGLU, LoRA r=8 on all 7
//! projections, seq 128) for a few hundred steps on the synthetic corpus
//! and log the loss curve — proving all three layers compose at scale.
//!
//!     cargo run --release --example train_100m -- [steps] [method]
//!
//! Results are appended to EXPERIMENTS.md §E2E by hand; the JSONL metrics
//! land in runs/e2e100m-<method>.jsonl.

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::util::stats::fmt_mb;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(200);
    let method = Method::parse(args.get(1).map(|s| s.as_str()).unwrap_or("mesp"))?;

    let cfg = TrainConfig {
        config: "e2e100m".into(),
        method,
        steps,
        lr: 3e-4,
        optimizer: mesp::config::OptimizerKind::parse("adam")?,
        seed: 42,
        log_every: 10,
        metrics_path: Some(format!("runs/e2e100m-{}.jsonl",
                                   method.name().to_lowercase())),
        ..Default::default()
    };

    println!("== e2e100m: ~98M params, {} , {steps} steps ==", method.name());
    let t0 = std::time::Instant::now();
    let mut sess = TrainSession::builder(cfg).build()?;
    let summary = sess.run(steps)?;
    let losses = sess.losses();

    println!("\nloss curve (every {} steps):", (steps / 20).max(1));
    for (i, l) in losses.iter().enumerate().step_by((steps / 20).max(1)) {
        let bar = "#".repeat(((l / losses[0]) * 40.0) as usize);
        println!("  step {:>5}  {l:.4}  {bar}", i + 1);
    }
    println!("\nfinal loss {:.4} (from {:.4})", summary.final_loss, losses[0]);
    println!("peak tracked memory {} MB", fmt_mb(summary.peak_bytes));
    println!("mean step time {:.2}s, total {:.1}s",
             summary.mean_step_secs, t0.elapsed().as_secs_f64());
    println!("\nper-artifact execution profile:");
    for (name, s) in sess.engine.ctx().rt.exec_stats() {
        println!("  {name:<22} {:>6} calls  {:>9.2}s", s.calls, s.total_secs);
    }
    Ok(())
}
