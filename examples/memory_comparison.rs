//! The paper's core comparison, measured live: run all four strategies on
//! the same model/data/seed and report tracked peak memory + step time —
//! the on-testbed analogue of Tables 1 and 5 — then print the analytical
//! model's Qwen-scale projection next to the paper's numbers.
//!
//!     cargo run --release --example memory_comparison -- [config] [steps]

use mesp::config::{presets, Method, TrainConfig};
use mesp::coordinator::sweep_methods;
use mesp::memory::model as memmodel;
use mesp::metrics::tables::TableBuilder;
use mesp::util::stats::fmt_mb;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let config = args.first().cloned().unwrap_or_else(|| "small".into());
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(5);

    println!("== measured on this machine: config {config}, {steps} steps ==\n");
    let base = TrainConfig { config, log_every: usize::MAX,
                             ..Default::default() };
    let methods = [Method::Mebp, Method::Mezo, Method::StoreH, Method::Mesp];
    let runs = sweep_methods(&base, &methods, steps)?;
    let mebp_peak = runs[0].1.peak_bytes as f64;
    let mebp_time = runs[0].1.mean_step_secs;

    let mut t = TableBuilder::new(&[
        "Method", "peak MB", "vs MeBP", "s/step", "time vs MeBP",
    ]);
    for (m, s, _) in &runs {
        t.row(vec![
            m.name().into(),
            fmt_mb(s.peak_bytes),
            format!("{:+.0}%", 100.0 * (s.peak_bytes as f64 / mebp_peak - 1.0)),
            format!("{:.3}", s.mean_step_secs),
            format!("{:.2}x", s.mean_step_secs / mebp_time),
        ]);
    }
    println!("{}", t.render());

    println!("== analytical model at the paper's Qwen2.5 dims (seq 256, r8) ==\n");
    let mut t2 = TableBuilder::new(&[
        "Model", "Method", "ours MB", "paper MB", "ours red.", "paper red.",
    ]);
    let paper: &[(&str, [(f64, f64); 3])] = &[
        // (model, [(mebp, red), (mezo, red), (mesp, red)]) from Table 1
        ("0.5b", [(360.8, 0.0), (243.0, 33.0), (136.2, 62.0)]),
        ("1.5b", [(516.2, 0.0), (376.0, 27.0), (262.6, 49.0)]),
        ("3b", [(637.6, 0.0), (479.2, 25.0), (368.4, 42.0)]),
    ];
    for (model, rows) in paper {
        let dims = presets::by_name(model, 256, 8)?;
        for (i, m) in [Method::Mebp, Method::Mezo, Method::Mesp].iter().enumerate() {
            let ours = memmodel::peak_bytes(*m, &dims);
            t2.row(vec![
                model.to_uppercase(),
                m.name().into(),
                fmt_mb(ours),
                format!("{:.1}", rows[i].0),
                format!("{:.0}%", memmodel::reduction_vs_mebp(*m, &dims)),
                format!("{:.0}%", rows[i].1),
            ]);
        }
    }
    println!("{}", t2.render());
    Ok(())
}
