//! Why does MeZO converge slowly? (paper §5.6, Table 3)
//!
//! Computes exact LoRA gradients (MeSP) and the MeZO SPSA estimate on the
//! same batch and model state, then reports per-layer cosine similarity,
//! sign agreement and relative error — reproducing the paper's finding
//! that zeroth-order estimates are essentially uncorrelated with truth.
//!
//!     cargo run --release --example gradient_quality -- [config] [n_batches]

use mesp::config::{Method, TrainConfig};
use mesp::coordinator::TrainSession;
use mesp::metrics::{gradqual, grad_quality};
use mesp::metrics::tables::TableBuilder;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let config = args.first().cloned().unwrap_or_else(|| "small".into());
    let n_batches: usize =
        args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(3);

    let base = TrainConfig { config, log_every: usize::MAX,
                             ..Default::default() };
    let mut agg: Vec<gradqual::GradQuality> = Vec::new();

    for b in 0..n_batches {
        let mut cfg_e = base.clone();
        cfg_e.method = Method::Mesp;
        cfg_e.seed = 42 + b as u64;
        let mut exact_s = TrainSession::builder(cfg_e).build()?;
        let (batch, _g) = exact_s.loader.next();
        let exact = exact_s.engine.gradients(&batch)?;

        let mut cfg_z = base.clone();
        cfg_z.method = Method::Mezo;
        cfg_z.seed = 42 + b as u64;
        let mut mezo_s = TrainSession::builder(cfg_z).build()?;
        let est = mezo_s.engine.gradients(&batch)?;

        let rows = grad_quality(&est, &exact);
        if agg.is_empty() {
            agg = rows;
        } else {
            for (a, r) in agg.iter_mut().zip(rows) {
                a.cosine += r.cosine;
                a.sign_agree += r.sign_agree;
                a.rel_error += r.rel_error;
            }
        }
    }
    for a in &mut agg {
        a.cosine /= n_batches as f64;
        a.sign_agree /= n_batches as f64;
        a.rel_error /= n_batches as f64;
    }

    println!("== MeZO gradient quality vs exact ({n_batches} batches) ==\n");
    let mut t = TableBuilder::new(&[
        "Layer", "Cosine", "Sign agree", "Rel. error",
    ]);
    for r in &agg {
        t.row(vec![
            r.layer.to_string(),
            format!("{:.4}", r.cosine),
            format!("{:.1}%", 100.0 * r.sign_agree),
            format!("{:.1}", r.rel_error),
        ]);
    }
    let avg = gradqual::average(&agg);
    t.row(vec![
        "Avg".into(),
        format!("{:.4}", avg.cosine),
        format!("{:.1}%", 100.0 * avg.sign_agree),
        format!("{:.1}", avg.rel_error),
    ]);
    println!("{}", t.render());
    println!("paper (Qwen2.5-0.5B): cosine ≈ 0.001, sign ≈ 48.4%, rel err ~1978");
    println!("→ SPSA directions are chance-level; this is why MeZO needs");
    println!("  10-100x more steps and still converges to a worse loss.");
    Ok(())
}
