//! Fleet demo: the paper's budget argument as a serving-path experiment.
//!
//!     cargo run --release --example fleet_demo
//!
//! Runs the SAME device budget twice — once with an all-MeBP job grid,
//! once all-MeSP — and prints how many sessions each method fit
//! concurrently. The budget is sized so exactly one MeBP toy session
//! fits (the "fine-tuning must coexist with everything else" scenario);
//! MeSP's lower predicted peak lets the admission gate overlap several
//! sessions in the same envelope.

use mesp::config::{Method, TrainConfig};
use mesp::fleet::{grid, job_cost_bytes, FleetOptions, JobSpec, Scheduler};
use mesp::util::stats::fmt_mb;

fn main() -> anyhow::Result<()> {
    let base = TrainConfig {
        config: "toy".into(),
        steps: 25,
        log_every: usize::MAX,
        ..Default::default()
    };

    let cost_of = |method: Method| -> anyhow::Result<u64> {
        let mut spec = JobSpec::from_base(&base);
        spec.method = method;
        job_cost_bytes(&spec)
    };
    let mebp_cost = cost_of(Method::Mebp)?;
    let mesp_cost = cost_of(Method::Mesp)?;
    // Big enough for one MeBP session, too small for two.
    let budget = 2 * mebp_cost - 1;
    println!("== fleet demo: shared budget {} MB ==", fmt_mb(budget));
    println!(
        "predicted per-session peak: MeBP {} MB, MeSP {} MB\n",
        fmt_mb(mebp_cost),
        fmt_mb(mesp_cost)
    );

    let opts = FleetOptions {
        budget_bytes: budget,
        workers: 4,
        ..FleetOptions::default()
    };
    let mut concurrency = Vec::new();
    for method in [Method::Mebp, Method::Mesp] {
        println!("--- {} fleet: 6 jobs ---", method.name());
        let report = Scheduler::run(&opts, &base, grid(&base, &[method], 6))?;
        print!("{}", report.render());
        println!();
        anyhow::ensure!(report.failed() == 0, "fleet jobs failed");
        concurrency.push((method, report.peak_concurrent));
    }

    println!("same budget, peak concurrent sessions:");
    for (method, peak) in &concurrency {
        println!("  {:<8} {peak}", method.name());
    }
    println!(
        "\nMeSP's structured backward buys concurrency, not just headroom: \
         the admission gate fits {}x the sessions MeBP gets.",
        concurrency[1].1 as f64 / concurrency[0].1.max(1) as f64
    );
    Ok(())
}
